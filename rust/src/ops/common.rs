//! Shared kernel machinery: padding arithmetic, fused-activation ranges,
//! and prepared quantization state.
//!
//! Everything here mirrors TFLite's kernel_util definitions so that int8
//! inference is bit-exact with the TFLite quantization spec the paper's
//! benchmark models use (§5.1: "Our benchmarks are INT8 TensorFlow Lite
//! models").

use crate::error::Result;
use crate::schema::format::{Activation, Padding};
use crate::tensor::{QuantizedMultiplier, TensorMeta};

/// Shared TFLite int8 add/mul requantization constants: returns
/// `(left_shift, mult1, mult2, mult_out)` for the shifted-add scheme
/// (`is_mul == false`; TFLite `kLeftShift` = 20, also used by Sub) or the
/// plain product rescale (`is_mul == true`; `mult1`/`mult2` unused). One
/// helper so the elementwise kernel's prepare and the fused-epilogue
/// prepare ([`FusedArith::from_spec`]) cannot drift: both paths must
/// produce bit-identical multipliers for the rewriter's Add/Mul folding
/// to be exact.
pub fn arith_i8_multipliers(
    is_mul: bool,
    s1: f64,
    s2: f64,
    so: f64,
) -> Result<(i32, QuantizedMultiplier, QuantizedMultiplier, QuantizedMultiplier)> {
    if is_mul {
        let mult_out = QuantizedMultiplier::try_from_real(s1 * s2 / so)?;
        Ok((0, QuantizedMultiplier::default(), QuantizedMultiplier::default(), mult_out))
    } else {
        // TFLite: kLeftShift = 20.
        let left_shift = 20;
        let twice_max = 2.0 * s1.max(s2);
        let mult1 = QuantizedMultiplier::try_from_real(s1 / twice_max)?;
        let mult2 = QuantizedMultiplier::try_from_real(s2 / twice_max)?;
        let mult_out =
            QuantizedMultiplier::try_from_real(twice_max / ((1i64 << left_shift) as f64 * so))?;
        Ok((left_shift, mult1, mult2, mult_out))
    }
}

/// A scalar Add/Mul (+ optional trailing activation) folded into the
/// requant epilogue of a producing conv/FC by the graph rewriter
/// ([`crate::rewriter`]).
///
/// The producer requantizes against the recorded *intermediate*
/// quantization (`inter_scale`/`inter_zp` — the elided elementwise op's
/// first input, i.e. the producer's original output tensor) with no
/// activation clamp beyond the i8 range, then applies [`FusedArith`] in
/// place over its output slice. That two-step pipeline reproduces the
/// standalone elementwise kernel's int8 arithmetic bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FusedSpec {
    /// True for Mul, false for Add.
    pub is_mul: bool,
    /// The elided elementwise op's fused activation.
    pub act: Activation,
    /// The constant scalar operand's quantized (i8) value.
    pub const_val: i32,
    /// The constant operand's scale.
    pub const_scale: f32,
    /// The constant operand's zero point.
    pub const_zp: i32,
    /// Intermediate (producer-output) scale.
    pub inter_scale: f32,
    /// Intermediate (producer-output) zero point.
    pub inter_zp: i32,
}

/// Invoke-time state of one fused scalar Add/Mul epilogue, precomputed at
/// prepare time so the per-invoke body is integer-only.
#[derive(Debug, Clone, Copy)]
pub struct FusedArith {
    is_mul: bool,
    left_shift: i32,
    mult1: QuantizedMultiplier,
    mult2: QuantizedMultiplier,
    mult_out: QuantizedMultiplier,
    /// -intermediate zero point.
    offset1: i32,
    /// -constant zero point.
    offset2: i32,
    /// Final-output zero point.
    offset_out: i32,
    const_val: i32,
    act_min: i32,
    act_max: i32,
}

impl FusedArith {
    /// Build from a rewrite record and the op's final output tensor.
    pub fn from_spec(f: &FusedSpec, out: &TensorMeta) -> Result<FusedArith> {
        let (left_shift, mult1, mult2, mult_out) = arith_i8_multipliers(
            f.is_mul,
            f.inter_scale as f64,
            f.const_scale as f64,
            out.scale()? as f64,
        )?;
        let (act_min, act_max) = activation_range_i8(f.act, out)?;
        Ok(FusedArith {
            is_mul: f.is_mul,
            left_shift,
            mult1,
            mult2,
            mult_out,
            offset1: -f.inter_zp,
            offset2: -f.const_zp,
            offset_out: out.zero_point()?,
            const_val: f.const_val,
            act_min,
            act_max,
        })
    }

    /// Apply the epilogue in place over the producer's output slice — the
    /// elementwise kernel's int8 body with the scalar operand's rescale
    /// hoisted out of the loop.
    // lint:alloc_free
    pub fn apply(&self, out: &mut [i8]) {
        let vb = self.const_val + self.offset2;
        let sb = if self.is_mul { 0 } else { self.mult2.apply(vb << self.left_shift) };
        for o in out.iter_mut() {
            let va = *o as i32 + self.offset1;
            let raw = if self.is_mul {
                self.mult_out.apply(va * vb)
            } else {
                let sa = self.mult1.apply(va << self.left_shift);
                self.mult_out.apply(sa + sb)
            } + self.offset_out;
            *o = raw.clamp(self.act_min, self.act_max) as i8;
        }
    }
}

/// Computed spatial padding for one dimension pair.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PaddingValues {
    /// Zero rows added above.
    pub top: i32,
    /// Zero columns added to the left.
    pub left: i32,
}

/// Output spatial extent for a conv/pool dimension (TFLite semantics).
///
/// Clamped to 0: with VALID padding a (dilated) filter larger than the
/// input would otherwise yield a *negative* extent that flows silently
/// into downstream shape math. TFLite rejects such geometry at prepare;
/// the prepare paths here do the same by erroring when this returns a
/// non-positive extent (see `prepare_conv` / `prepare_depthwise` /
/// pooling prepare).
pub fn compute_out_size(padding: Padding, in_size: i32, filter: i32, stride: i32, dilation: i32) -> i32 {
    let effective = (filter - 1) * dilation + 1;
    let raw = match padding {
        Padding::Same => (in_size + stride - 1) / stride,
        Padding::Valid => (in_size - effective + stride) / stride,
    };
    raw.max(0)
}

/// The shared prepare-time rejection behind [`compute_out_size`]'s
/// clamp: a non-positive computed extent means the (dilated) filter or
/// pool window exceeds the input under this padding, and prepare must
/// surface an error instead of letting a zero extent into shape math
/// (TFLite rejects the geometry too). Returns the failure reason, or
/// `None` when both extents are positive. One helper so conv, depthwise,
/// and pooling cannot drift.
#[allow(clippy::too_many_arguments)]
pub fn filter_exceeds_input(
    want_h: i32,
    want_w: i32,
    kh: i32,
    kw: i32,
    dil_h: i32,
    dil_w: i32,
    in_h: i32,
    in_w: i32,
    padding: Padding,
) -> Option<String> {
    if want_h > 0 && want_w > 0 {
        return None;
    }
    Some(format!(
        "filter {kh}x{kw} (dilation {dil_h}x{dil_w}) exceeds input {in_h}x{in_w} \
         under {padding:?} padding"
    ))
}

/// Padding offset (top/left) for one dimension (TFLite `ComputePadding`).
pub fn compute_padding(stride: i32, dilation: i32, in_size: i32, filter: i32, out_size: i32) -> i32 {
    let effective = (filter - 1) * dilation + 1;
    let padding = ((out_size - 1) * stride + effective - in_size) / 2;
    padding.max(0)
}

/// Clamp range implied by a fused activation on f32 data.
pub fn activation_range_f32(act: Activation) -> (f32, f32) {
    match act {
        Activation::None => (f32::NEG_INFINITY, f32::INFINITY),
        Activation::Relu => (0.0, f32::INFINITY),
        Activation::Relu6 => (0.0, 6.0),
    }
}

/// Validate and return an i8 tensor's zero point.
///
/// The TMF schema bounds zero points to the 16-bit range (it must cover
/// every quantized dtype), so a corrupt or adversarial model can carry
/// an i8 tensor whose zero point is far outside `[-128, 127]`. Kernels
/// that *use* the zero point as an i8 value (Pad's fill byte, ReLU's
/// quantized clamp floor, Mean's correction term) must reject that at
/// prepare time — a silent `as i8` wrap produces wrong fills, and a
/// clamp floor above the ceiling panics. Returns the zero point when in
/// range; the caller wraps the error with `ctx.fail` so it surfaces as
/// an invalid-model prepare failure.
pub fn i8_zero_point(meta: &TensorMeta, what: &str) -> Result<i32> {
    let zp = meta.zero_point()?;
    if !(i8::MIN as i32..=i8::MAX as i32).contains(&zp) {
        return Err(crate::error::Error::MalformedModel(format!(
            "{what} tensor '{}': zero point {zp} outside the i8 range [-128, 127]",
            meta.name
        )));
    }
    Ok(zp)
}

/// Clamp range implied by a fused activation on int8 data, in the output's
/// quantized domain (TFLite `CalculateActivationRangeQuantized`).
pub fn activation_range_i8(act: Activation, out: &TensorMeta) -> Result<(i32, i32)> {
    let scale = out.scale()?;
    let zp = out.zero_point()?;
    let quantize = |v: f32| -> i32 { (v / scale).round() as i32 + zp };
    let (lo, hi) = match act {
        Activation::None => (i8::MIN as i32, i8::MAX as i32),
        Activation::Relu => (quantize(0.0).max(i8::MIN as i32), i8::MAX as i32),
        Activation::Relu6 => (
            quantize(0.0).max(i8::MIN as i32),
            quantize(6.0).min(i8::MAX as i32),
        ),
    };
    Ok((lo, hi.max(lo)))
}

/// Prepared per-output-channel requantization entry.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChannelQuant {
    /// Fixed-point output multiplier for this channel.
    pub mult: QuantizedMultiplier,
}

/// Handles to prepare-time packed-weight state (optimized kernels only).
///
/// Filled during the populate pass; `None` fields / absence mean the
/// kernel falls back to the unpacked invoke path (non-constant weights,
/// unsupported geometry).
#[derive(Debug, Clone, Copy)]
pub struct PackedSpec {
    /// Channel-blocked repacked filter: the GEMM layout
    /// ([`crate::ops::opt_ops::gemm::pack_filter`]) for conv/FC, the
    /// depthwise lane-blocked layout
    /// ([`crate::ops::opt_ops::depthwise::pack_depthwise_filter`]) for
    /// depthwise. `None` when only biases are folded (depthwise layers
    /// thinner than one channel block).
    pub filter: Option<crate::ops::PersistentHandle>,
    /// Folded per-channel bias: `bias[oc] + input_offset * Σ filter[oc]`,
    /// one i32 per output channel.
    pub fused_bias: crate::ops::PersistentHandle,
}

/// Prepared state for conv-style kernels.
#[derive(Debug, Default)]
pub struct ConvData {
    /// Computed padding offsets.
    pub pad: PaddingValues,
    /// Output spatial height.
    pub out_h: i32,
    /// Output spatial width.
    pub out_w: i32,
    /// Per-output-channel requantization multipliers (len = out channels;
    /// per-tensor quantization repeats one entry).
    pub per_channel: Vec<ChannelQuant>,
    /// -input zero point, applied to each input element.
    pub input_offset: i32,
    /// Output zero point.
    pub output_offset: i32,
    /// Quantized activation clamp (min, max).
    pub act_min: i32,
    /// Quantized activation clamp max.
    pub act_max: i32,
    /// Float activation clamp, for f32 models.
    pub fact: (f32, f32),
    /// Packed-weight / folded-bias handles (optimized int8 path only).
    pub packed: Option<PackedSpec>,
    /// Rewriter-fused scalar Add/Mul epilogue, applied in place after
    /// requantization (see [`FusedSpec`]).
    pub fused: Option<FusedArith>,
}

/// Prepared state for fully-connected kernels.
#[derive(Debug, Default)]
pub struct FcData {
    /// Requantization multiplier (per-tensor).
    pub mult: QuantizedMultiplier,
    /// -input zero point.
    pub input_offset: i32,
    /// -filter zero point.
    pub filter_offset: i32,
    /// Output zero point.
    pub output_offset: i32,
    /// Quantized activation clamp min.
    pub act_min: i32,
    /// Quantized activation clamp max.
    pub act_max: i32,
    /// Float activation clamp.
    pub fact: (f32, f32),
    /// Packed-weight / folded-bias handles (optimized int8 path only).
    pub packed: Option<PackedSpec>,
    /// Rewriter-fused scalar Add/Mul epilogue, applied in place after
    /// requantization (see [`FusedSpec`]).
    pub fused: Option<FusedArith>,
}

/// Prepared state for pooling kernels.
#[derive(Debug, Default)]
pub struct PoolData {
    /// Computed padding offsets.
    pub pad: PaddingValues,
    /// Output spatial height.
    pub out_h: i32,
    /// Output spatial width.
    pub out_w: i32,
    /// Quantized activation clamp min.
    pub act_min: i32,
    /// Quantized activation clamp max.
    pub act_max: i32,
    /// Float activation clamp.
    pub fact: (f32, f32),
}

/// Prepared state for softmax (int8 path uses scaled-diff exponent table
/// semantics; we precompute the input scaling).
#[derive(Debug, Default)]
pub struct SoftmaxData {
    /// beta * input_scale, folded for the exp argument.
    pub beta_scale: f32,
    /// Output scale (for quantizing the result).
    pub out_scale: f32,
    /// Output zero point.
    pub out_zp: i32,
}

/// Prepared state for quantized elementwise add/mul.
#[derive(Debug, Default)]
pub struct ArithData {
    /// Left shift applied before per-input rescaling (TFLite uses 20).
    pub left_shift: i32,
    /// Input-1 rescale.
    pub mult1: QuantizedMultiplier,
    /// Input-2 rescale.
    pub mult2: QuantizedMultiplier,
    /// Output rescale.
    pub mult_out: QuantizedMultiplier,
    /// -input1 zero point.
    pub offset1: i32,
    /// -input2 zero point.
    pub offset2: i32,
    /// Output zero point.
    pub offset_out: i32,
    /// Quantized activation clamp min.
    pub act_min: i32,
    /// Quantized activation clamp max.
    pub act_max: i32,
    /// Float activation clamp.
    pub fact: (f32, f32),
}

/// Prepared state for quantize/requantize.
#[derive(Debug, Default)]
pub struct RequantData {
    /// effective scale in/out as a fixed-point multiplier.
    pub mult: QuantizedMultiplier,
    /// Input zero point.
    pub in_zp: i32,
    /// Output zero point.
    pub out_zp: i32,
    /// Input scale (float → int8 path).
    pub in_scale: f32,
    /// Output scale.
    pub out_scale: f32,
}

/// Prepared state for mean reduction.
#[derive(Debug, Default)]
pub struct MeanData {
    /// Resolved (non-negative) axes to reduce.
    pub axes: Vec<usize>,
    /// Number of elements reduced per output element.
    pub divisor: i32,
    /// Requantization multiplier folding in/out scales and the divisor.
    pub mult: QuantizedMultiplier,
    /// Input zero point.
    pub in_zp: i32,
    /// Output zero point.
    pub out_zp: i32,
}

/// Build per-channel conv requantization state.
///
/// effective_scale[c] = input_scale * filter_scale[c] / output_scale,
/// quantized to (multiplier, shift) pairs at prepare time.
pub fn conv_per_channel(
    input: &TensorMeta,
    filter: &TensorMeta,
    output: &TensorMeta,
    out_channels: usize,
) -> Result<Vec<ChannelQuant>> {
    let in_scale = input.scale()? as f64;
    let out_scale = output.scale()? as f64;
    let fq = filter
        .quant
        .as_ref()
        .ok_or_else(|| crate::error::Error::InvalidTensor("filter not quantized".into()))?;
    let mut v = Vec::with_capacity(out_channels);
    for c in 0..out_channels {
        let fs = if fq.scales.len() == 1 { fq.scales[0] } else { fq.scales[c] } as f64;
        // try_from_real: a broken per-channel scale (negative, zero
        // output scale → inf/NaN ratio) must fail prepare, not encode
        // a garbage multiplier.
        v.push(ChannelQuant { mult: QuantizedMultiplier::try_from_real(in_scale * fs / out_scale)? });
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{DType, QuantParams, Shape};

    fn quant_meta(scale: f32, zp: i32) -> TensorMeta {
        TensorMeta {
            name: "t".into(),
            dtype: DType::I8,
            shape: Shape::new(vec![1]),
            buffer: None,
            quant: Some(QuantParams::per_tensor(scale, zp)),
            is_variable: false,
        }
    }

    #[test]
    fn out_size_same_vs_valid() {
        // 96x96 input, 3x3 filter, stride 2 (first VWW conv).
        assert_eq!(compute_out_size(Padding::Same, 96, 3, 2, 1), 48);
        assert_eq!(compute_out_size(Padding::Valid, 96, 3, 2, 1), 47);
        // stride 1.
        assert_eq!(compute_out_size(Padding::Same, 10, 3, 1, 1), 10);
        assert_eq!(compute_out_size(Padding::Valid, 10, 3, 1, 1), 8);
    }

    /// Regression: a VALID filter larger than the input used to return a
    /// *negative* extent ((2 - 5 + 1)/1 = -2) that flowed into shape
    /// math; it must clamp to 0 (and prepare turns 0 into an error).
    #[test]
    fn out_size_valid_filter_exceeding_input_clamps_to_zero() {
        assert_eq!(compute_out_size(Padding::Valid, 2, 5, 1, 1), 0);
        // Dilation makes the *effective* filter exceed the input:
        // effective = (3-1)*3 + 1 = 7 > 4.
        assert_eq!(compute_out_size(Padding::Valid, 4, 3, 1, 3), 0);
        // Exact fit is still 1, one past it is 0 (boundary).
        assert_eq!(compute_out_size(Padding::Valid, 5, 5, 1, 1), 1);
        assert_eq!(compute_out_size(Padding::Valid, 4, 5, 1, 1), 0);
        // Large stride cannot push a legitimate case negative.
        assert_eq!(compute_out_size(Padding::Valid, 2, 5, 7, 1), 0);
    }

    #[test]
    fn padding_offsets() {
        // SAME 3x3 stride 1 over 10 -> pad 1.
        assert_eq!(compute_padding(1, 1, 10, 3, 10), 1);
        // SAME 3x3 stride 2 over 96 -> out 48, pad floor(((48-1)*2+3-96)/2)=0
        assert_eq!(compute_padding(2, 1, 96, 3, 48), 0);
        // VALID never needs padding.
        assert_eq!(compute_padding(1, 1, 10, 3, 8), 0);
    }

    #[test]
    fn activation_ranges_f32() {
        assert_eq!(activation_range_f32(Activation::Relu6), (0.0, 6.0));
        let (lo, hi) = activation_range_f32(Activation::None);
        assert!(lo.is_infinite() && hi.is_infinite());
    }

    #[test]
    fn activation_ranges_i8() {
        // scale 0.1, zp -10: relu6 clamps to [q(0), q(6)] = [-10, 50].
        let out = quant_meta(0.1, -10);
        assert_eq!(activation_range_i8(Activation::Relu6, &out).unwrap(), (-10, 50));
        assert_eq!(activation_range_i8(Activation::Relu, &out).unwrap(), (-10, 127));
        assert_eq!(activation_range_i8(Activation::None, &out).unwrap(), (-128, 127));
    }

    #[test]
    fn per_channel_multipliers() {
        let input = quant_meta(0.5, 0);
        let output = quant_meta(0.25, 0);
        let mut filter = quant_meta(1.0, 0);
        filter.quant = Some(QuantParams::per_axis(vec![0.5, 1.0], vec![0, 0], 0));
        let pc = conv_per_channel(&input, &filter, &output, 2).unwrap();
        // effective scales: 0.5*0.5/0.25 = 1.0 and 0.5*1.0/0.25 = 2.0.
        assert_eq!(pc[0].mult.apply(100), 100);
        assert_eq!(pc[1].mult.apply(100), 200);
    }

    #[test]
    fn per_tensor_filter_broadcasts() {
        let input = quant_meta(1.0, 0);
        let output = quant_meta(1.0, 0);
        let filter = quant_meta(0.5, 0);
        let pc = conv_per_channel(&input, &filter, &output, 4).unwrap();
        assert_eq!(pc.len(), 4);
        for c in &pc {
            assert_eq!(c.mult.apply(64), 32);
        }
    }
}
