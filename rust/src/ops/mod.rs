//! Operators: the calculation units of the graph (§4.7).
//!
//! The full model lifecycle is **load → validate → rewrite → prepare →
//! plan → populate → invoke**: after structural validation and before any
//! kernel runs, the graph rewriter ([`crate::rewriter`]) folds Pad ops
//! into conv padding, elides no-op view ops, and fuses scalar Add/Mul
//! epilogues (unless `Options::skip_rewrite`). Kernels themselves follow
//! TF Micro's **prepare → plan → populate → invoke** protocol over the
//! (possibly rewritten) graph:
//!
//! 1. **prepare** — called once per op during interpreter initialization.
//!    The kernel validates shapes/dtypes, precomputes quantization state
//!    (fixed-point multipliers, activation ranges), requests invoke-time
//!    scratch *and* interpreter-lifetime persistent buffers
//!    ([`PrepareContext::request_scratch`] /
//!    [`PrepareContext::request_persistent`]), and stores per-op data.
//!    All allocation requests happen here.
//! 2. **plan** — interpreter-side: scratch lifetimes are analyzed, the
//!    memory planner places every intermediate tensor, persistent buffers
//!    are carved from the arena's tail section, and the arena is sealed.
//! 3. **populate** — called once per op after the plan is final. The
//!    kernel fills the persistent buffers it requested — repacked weight
//!    layouts, folded biases, lookup tables — reading constant tensors
//!    through the same [`OpContext`] it will see at invoke time. This is
//!    where model-constant work is hoisted out of the inference path
//!    (the CMSIS-NN "kernel sums" trick, §4.7–§4.8): anything derivable
//!    from weights + quantization params is computed exactly once. The
//!    stage covers **vendor/accelerated kernels too**: the XLA/PJRT FC
//!    kernel ([`crate::runtime::XlaFcKernel`]) compiles its HLO artifact,
//!    stages weight/bias/requant literals, and runs one warm-up execution
//!    here, and SIMD backends build their populate-time side tables (the
//!    AVX-VNNI `-128·Σf` compensation cache) — so no first-invoke ever
//!    pays compilation, upload, or precompute cost. Off-arena bytes such
//!    kernels hold are charged via
//!    [`PrepareContext::charge_kernel_external`].
//! 4. **invoke** — called on every inference. Pure computation over
//!    tensor views; no allocation (the arena is sealed by then), no
//!    recomputation of model-constant values, and — for accelerated
//!    kernels — no compilation or weight upload: input transfer +
//!    execute only.
//!
//! The boundary is intentionally narrow — the kernel sees only
//! [`PrepareContext`] / [`OpContext`], never interpreter internals —
//! which is the crate's analog of the paper's C-API boundary ("to ensure
//! operator implementations are modular and independent of the
//! interpreter", §4.1). Swapping a reference kernel for a vendor-optimized
//! one is a registration change, not an interpreter change (§4.8).
//!
//! Kernel families:
//! * [`ref_ops`] — portable reference implementations, readability first
//!   (the paper's reference kernels).
//! * [`opt_ops`] — host-optimized implementations (the CMSIS-NN analog;
//!   see DESIGN.md §6.2).
//! * XLA/PJRT-backed kernels live in [`crate::runtime`] and register
//!   through the same [`resolver::OpResolver`].

pub mod common;
pub mod opt_ops;
pub mod ref_ops;
pub mod resolver;

pub use resolver::OpResolver;

use crate::error::{Error, Result};
use crate::schema::{Model, Operator};
use crate::tensor::{DType, TensorMeta};
use std::sync::atomic::AtomicBool;

/// Where a tensor's storage lives at run time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataLoc {
    /// Constant data inside the serialized model (zero-copy weights).
    Const {
        /// Byte offset into the model data.
        off: usize,
        /// Length in bytes.
        len: usize,
    },
    /// Arena-resident data at a planner-assigned offset.
    Arena {
        /// Byte offset into the arena.
        off: usize,
        /// Length in bytes.
        len: usize,
    },
}

/// Which implementation family a kernel belongs to (used by benches and
/// the platform cycle model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFlavor {
    /// Simple, portable, readable (paper's reference kernels).
    Reference,
    /// Platform-optimized Rust (the CMSIS-NN analog).
    Optimized,
    /// Offloaded to an AOT-compiled XLA executable via PJRT
    /// (the vendor-library analog, DESIGN.md §6.2).
    Accelerated,
}

/// Handle to a scratch buffer requested during prepare.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScratchHandle(pub(crate) usize);

/// Handle to a kernel-owned persistent buffer requested during prepare.
///
/// Persistent buffers live in the arena's tail (interpreter-lifetime)
/// section, are filled once during the populate pass, and are read-only
/// thereafter. They hold prepare-time precomputation products: repacked
/// weights, folded biases, lookup tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PersistentHandle(pub(crate) usize);

/// Per-op state computed during prepare and read during invoke.
///
/// A concrete enum (rather than `dyn Any`) keeps invoke-path access
/// branch-cheap; `Custom` is the escape hatch for out-of-tree kernels.
#[derive(Debug)]
pub enum OpData {
    /// No prepared state.
    None,
    /// Conv / depthwise-conv prepared state.
    Conv(common::ConvData),
    /// Fully-connected prepared state.
    FullyConnected(common::FcData),
    /// Pooling prepared state.
    Pool(common::PoolData),
    /// Softmax prepared state.
    Softmax(common::SoftmaxData),
    /// Quantized elementwise add/mul prepared state.
    Arith(common::ArithData),
    /// Quantize/requantize prepared state.
    Requant(common::RequantData),
    /// Mean-reduction prepared state.
    Mean(common::MeanData),
    /// Out-of-tree kernel state.
    Custom(Box<dyn std::any::Any + Send + Sync>),
}

impl OpData {
    /// Approximate arena footprint of this state, charged against the
    /// persistent (tail) section so Table 2 accounting stays honest even
    /// though host builds keep the state on the heap.
    pub fn arena_bytes(&self) -> usize {
        let heap = match self {
            OpData::Conv(c) => c.per_channel.len() * 8,
            OpData::Custom(_) => 64, // conservative flat charge
            _ => 0,
        };
        std::mem::size_of::<OpData>() + heap
    }
}

/// A kernel implementation registered for one operator type.
pub trait Kernel: Send + Sync {
    /// Implementation family (reference / optimized / accelerated).
    fn flavor(&self) -> KernelFlavor {
        KernelFlavor::Reference
    }

    /// Validate and precompute; called once at initialization.
    fn prepare(&self, ctx: &mut PrepareContext) -> Result<()>;

    /// Fill persistent buffers requested during prepare; called once after
    /// the memory plan is sealed (the populate pass). Kernels without
    /// persistent state keep the no-op default.
    fn populate(&self, _ctx: &OpContext) -> Result<()> {
        Ok(())
    }

    /// Execute; called per inference, allocation-free.
    fn invoke(&self, ctx: &OpContext) -> Result<()>;

    /// True if this kernel honors a rewriter-fused scalar Add/Mul
    /// epilogue ([`common::FusedSpec`], delivered via
    /// [`PrepareContext::fused`]). The interpreter refuses to build a
    /// model whose rewrite metadata attaches a fused record to a kernel
    /// that keeps the `false` default, so kernels can't silently drop a
    /// fused op.
    fn supports_fused_epilogue(&self) -> bool {
        false
    }
}

/// Prepare-phase view of one op, handed to [`Kernel::prepare`].
pub struct PrepareContext<'m, 'i> {
    /// Index of this op in execution order.
    pub op_index: usize,
    /// The op's schema record (inputs/outputs/options).
    pub operator: &'m Operator,
    model: &'m Model,
    scratch_sizes: &'i mut Vec<usize>,
    persistent_sizes: &'i mut Vec<usize>,
    op_data: &'i mut OpData,
    persistent_bytes: &'i mut usize,
    external_bytes: &'i mut usize,
    fused: Option<common::FusedSpec>,
}

impl<'m, 'i> PrepareContext<'m, 'i> {
    /// Construct (interpreter-internal, but public for kernel unit tests).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        op_index: usize,
        operator: &'m Operator,
        model: &'m Model,
        scratch_sizes: &'i mut Vec<usize>,
        persistent_sizes: &'i mut Vec<usize>,
        op_data: &'i mut OpData,
        persistent_bytes: &'i mut usize,
        external_bytes: &'i mut usize,
    ) -> Self {
        PrepareContext {
            op_index,
            operator,
            model,
            scratch_sizes,
            persistent_sizes,
            op_data,
            persistent_bytes,
            external_bytes,
            fused: None,
        }
    }

    /// Attach a rewriter-fused scalar Add/Mul epilogue record for this op
    /// (the interpreter parses them from `tmf.rewrite.fused` metadata).
    pub fn with_fused(mut self, fused: Option<common::FusedSpec>) -> Self {
        self.fused = fused;
        self
    }

    /// The fused-epilogue record attached to this op, if any.
    pub fn fused(&self) -> Option<common::FusedSpec> {
        self.fused
    }

    /// Number of declared inputs (including omitted optionals).
    pub fn num_inputs(&self) -> usize {
        self.operator.inputs.len()
    }

    /// True if optional input `i` is present.
    pub fn has_input(&self, i: usize) -> bool {
        self.operator.inputs.get(i).map(|&t| t != -1).unwrap_or(false)
    }

    fn tensor_index(&self, list: &[i32], i: usize, what: &str) -> Result<usize> {
        let t = *list.get(i).ok_or_else(|| {
            Error::InvalidTensor(format!("{what} {i} out of range (op has {})", list.len()))
        })?;
        if t == -1 {
            return Err(Error::InvalidTensor(format!("{what} {i} is omitted")));
        }
        Ok(t as usize)
    }

    /// Metadata of input `i`.
    pub fn input(&self, i: usize) -> Result<&'m TensorMeta> {
        let t = self.tensor_index(&self.operator.inputs, i, "input")?;
        self.model.tensor(t)
    }

    /// Metadata of output `i`.
    pub fn output(&self, i: usize) -> Result<&'m TensorMeta> {
        let t = self.tensor_index(&self.operator.outputs, i, "output")?;
        self.model.tensor(t)
    }

    /// Constant data of input `i` (prepare-time access to weight/param
    /// tensors, e.g. `Pad` paddings or `Mean` axes).
    pub fn input_const(&self, i: usize) -> Result<&'m [u8]> {
        let t = self.tensor_index(&self.operator.inputs, i, "input")?;
        self.model.tensor_data(t)?.ok_or_else(|| {
            Error::InvalidTensor(format!("input {i} of op {} is not constant", self.op_index))
        })
    }

    /// Constant i32 data of input `i`.
    pub fn input_const_i32(&self, i: usize) -> Result<Vec<i32>> {
        let raw = self.input_const(i)?;
        if raw.len() % 4 != 0 {
            return Err(Error::InvalidTensor(format!("input {i}: not an i32 array")));
        }
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Request an invoke-time scratch buffer of `bytes`; its storage is
    /// planned into the non-persistent arena section with a lifetime of
    /// exactly this op (TF Micro's `RequestScratchBufferInArena`).
    pub fn request_scratch(&mut self, bytes: usize) -> ScratchHandle {
        self.scratch_sizes.push(bytes);
        ScratchHandle(self.scratch_sizes.len() - 1)
    }

    /// Request a kernel-owned persistent byte buffer of `bytes`.
    ///
    /// Storage comes from the arena's tail (interpreter-lifetime) section
    /// and is reported as `kernel_buffers` in [`crate::arena::ArenaUsage`].
    /// The kernel fills it once in [`Kernel::populate`] and reads it on
    /// every invoke via [`OpContext::persistent_bytes`] (TF Micro's
    /// `RequestPersistentBuffer`).
    pub fn request_persistent(&mut self, bytes: usize) -> PersistentHandle {
        self.persistent_sizes.push(bytes);
        PersistentHandle(self.persistent_sizes.len() - 1)
    }

    /// Charge `bytes` of kernel-held storage that lives **outside** the
    /// arena — host/device buffers owned by a vendor or XLA/PJRT-backed
    /// kernel (staged weight literals, compiled-executable I/O buffers).
    ///
    /// The interpreter folds the charge into
    /// [`crate::arena::ArenaUsage::kernel_buffers`] (and the persistent
    /// total) so `tfmicro mem` and `arena_usage_detail` report the true
    /// init-time memory footprint even when an accelerated kernel keeps
    /// its staged state off-arena.
    pub fn charge_kernel_external(&mut self, bytes: usize) {
        *self.external_bytes += bytes;
    }

    /// Store prepared per-op state; charged to the persistent section.
    pub fn set_op_data(&mut self, data: OpData) {
        *self.persistent_bytes += data.arena_bytes();
        *self.op_data = data;
    }

    /// Mutable access to already-stored per-op state, so an optimized
    /// kernel can layer extra prepared fields (e.g. packed-weight handles)
    /// on top of a shared prepare helper's output.
    pub fn op_data_mut(&mut self) -> &mut OpData {
        self.op_data
    }

    /// True if the op's weight tensor (input 1) and optional bias
    /// (input 2) are model constants — the precondition for prepare-time
    /// weight packing and bias folding.
    pub fn weights_are_const(&self) -> bool {
        self.input_const(1).is_ok() && (!self.has_input(2) || self.input_const(2).is_ok())
    }

    /// Convenience: error with this op's identity attached.
    pub fn fail(&self, reason: impl Into<String>) -> Error {
        Error::PrepareFailed {
            op_index: self.op_index,
            op_name: self.operator.opcode.name(),
            reason: reason.into(),
        }
    }
}

/// Invoke-phase view of one op, handed to [`Kernel::invoke`].
///
/// Data access goes through raw base pointers so a kernel can hold several
/// input slices and an output slice simultaneously.
///
/// # Safety invariants (upheld by the interpreter)
/// * Arena tensor ranges for simultaneously-live tensors are disjoint
///   (verified memory plan), so an op's inputs never alias its outputs.
/// * Scratch ranges are disjoint from all live tensor ranges.
/// * Persistent kernel buffers live in the tail section, disjoint from
///   the planned head region and from every other op's buffers.
/// * Constant ranges live in the immutable model bytes and are never
///   handed out mutably.
///
/// # Kernel contract
/// A kernel must not request the same tensor as both an input and an
/// output slice.
pub struct OpContext<'r> {
    /// Index of this op in execution order.
    pub op_index: usize,
    /// The op's schema record.
    pub operator: &'r Operator,
    tensors: &'r [TensorMeta],
    locs: &'r [DataLoc],
    model_data: &'r [u8],
    arena: *mut u8,
    arena_len: usize,
    /// (offset, len) of each scratch buffer this op requested.
    scratch: &'r [(usize, usize)],
    /// (offset, len) of each persistent buffer this op requested.
    persistent: &'r [(usize, usize)],
    op_data: &'r OpData,
    /// The owning interpreter's token (unique per interpreter build;
    /// [`crate::ops::opt_ops::gemm::NO_OWNER`] outside a lifecycle).
    owner: u64,
    /// Base of the persistent (tail) region. For `MicroInterpreter` this
    /// is the arena itself; for a [`crate::interpreter::PreparedModel`]
    /// persistent buffers live in a separate shared buffer so that many
    /// `ExecState` arenas can reference one copy of the packed weights.
    persist_base: *mut u8,
    persist_len: usize,
    /// Per-execution-state degrade flag for accelerated kernels. When
    /// present, an offload failure marks only this execution state as
    /// degraded instead of poisoning shared kernel state (`None` keeps
    /// the legacy per-kernel flag).
    degrade: Option<&'r AtomicBool>,
    /// True only during the single-threaded populate pass. Gates the
    /// `&mut` view of persistent buffers: at invoke time the persistent
    /// region may be shared by many workers through one
    /// `Arc<PreparedModel>`, so only the shared (`&[u8]`) view is legal.
    populate_phase: bool,
    /// Runtime batch multiplier `m`. The static graph shapes describe one
    /// request; a batched invoke lays `m` requests contiguously in every
    /// activation tensor, so kernels scale their leading (batch) dimension
    /// by this factor. Weights, biases, and all prepare/populate-time
    /// precomputation are batch-agnostic and ignore it. Always 1 for
    /// `MicroInterpreter` and for `PreparedModel::invoke`.
    batch: usize,
}

// SAFETY: `arena` points into memory exclusively borrowed (&mut) by the
// interpreter for its own lifetime; OpContext is only created inside
// `invoke` stack frames.
unsafe impl<'r> Send for OpContext<'r> {}

impl<'r> OpContext<'r> {
    /// Construct (interpreter-internal, public for kernel unit tests).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        op_index: usize,
        operator: &'r Operator,
        tensors: &'r [TensorMeta],
        locs: &'r [DataLoc],
        model_data: &'r [u8],
        arena: *mut u8,
        arena_len: usize,
        scratch: &'r [(usize, usize)],
        persistent: &'r [(usize, usize)],
        op_data: &'r OpData,
        owner: u64,
    ) -> Self {
        OpContext {
            op_index,
            operator,
            tensors,
            locs,
            model_data,
            arena,
            arena_len,
            scratch,
            persistent,
            op_data,
            owner,
            // Default: persistent buffers live inside the arena itself
            // (the MicroInterpreter layout).
            persist_base: arena,
            persist_len: arena_len,
            degrade: None,
            populate_phase: false,
            batch: 1,
        }
    }

    /// Mark this context as belonging to the populate pass, enabling
    /// mutable persistent-buffer access ([`OpContext::persistent_bytes`]).
    /// The interpreter sets this only on the single-threaded populate
    /// pass that runs before the model is ever shared.
    pub fn with_populate_phase(mut self) -> Self {
        self.populate_phase = true;
        self
    }

    /// Point persistent-buffer resolution at a region separate from the
    /// arena ([`crate::interpreter::PreparedModel`]'s shared tail buffer).
    pub fn with_persistent_region(mut self, base: *mut u8, len: usize) -> Self {
        self.persist_base = base;
        self.persist_len = len;
        self
    }

    /// Attach a per-execution-state degrade flag for accelerated kernels.
    pub fn with_degrade_flag(mut self, flag: &'r AtomicBool) -> Self {
        self.degrade = Some(flag);
        self
    }

    /// Set the runtime batch multiplier (see [`OpContext::batch`]).
    /// `m` must be ≥ 1; the interpreter only constructs batched contexts
    /// from a layout planned for that `m`, so every tensor/scratch range
    /// already holds `m` contiguous per-request lanes.
    pub fn with_batch(mut self, m: usize) -> Self {
        self.batch = m.max(1);
        self
    }

    /// Runtime batch multiplier `m` (1 for a plain single invoke).
    /// Kernels multiply their leading batch dimension by this; per-lane
    /// data is contiguous, so lane `b` of an `n`-element tensor occupies
    /// `[b*n, (b+1)*n)` of the (m·n)-element runtime slice.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Per-execution-state degrade flag, if the caller provided one.
    pub fn degrade_flag(&self) -> Option<&'r AtomicBool> {
        self.degrade
    }

    /// Prepared per-op state.
    pub fn op_data(&self) -> &'r OpData {
        self.op_data
    }

    /// The owning interpreter's token, unique per interpreter build.
    /// Kernels pass it to owner-scoped backend side tables
    /// ([`crate::ops::opt_ops::gemm::cache_packed_compensation`] /
    /// [`crate::ops::opt_ops::gemm::resolve_call_table`]) so cached state
    /// can never be served across interpreter lifetimes (the ABA guard).
    pub fn owner_token(&self) -> u64 {
        self.owner
    }

    /// True if optional input `i` is present.
    pub fn has_input(&self, i: usize) -> bool {
        self.operator.inputs.get(i).map(|&t| t != -1).unwrap_or(false)
    }

    /// True if input `i` is a model constant — the populate-pass
    /// precondition for staging weights into kernel-held buffers.
    pub fn input_is_const(&self, i: usize) -> bool {
        self.tensor_idx(&self.operator.inputs, i, "input")
            .map(|t| matches!(self.locs[t], DataLoc::Const { .. }))
            .unwrap_or(false)
    }

    fn tensor_idx(&self, list: &[i32], i: usize, what: &str) -> Result<usize> {
        let t = *list.get(i).ok_or_else(|| {
            Error::InvalidTensor(format!("{what} {i} out of range (op has {})", list.len()))
        })?;
        if t == -1 {
            return Err(Error::InvalidTensor(format!("{what} {i} is omitted")));
        }
        Ok(t as usize)
    }

    /// Metadata of input `i`.
    pub fn input(&self, i: usize) -> Result<&'r TensorMeta> {
        Ok(&self.tensors[self.tensor_idx(&self.operator.inputs, i, "input")?])
    }

    /// Metadata of output `i`.
    pub fn output(&self, i: usize) -> Result<&'r TensorMeta> {
        Ok(&self.tensors[self.tensor_idx(&self.operator.outputs, i, "output")?])
    }

    /// Planned storage location of input `i`. Lets a view kernel detect
    /// plan-level aliasing (input and output sharing one arena range)
    /// *before* materializing slices, and skip its copy.
    pub fn input_loc(&self, i: usize) -> Result<DataLoc> {
        Ok(self.locs[self.tensor_idx(&self.operator.inputs, i, "input")?])
    }

    /// Planned storage location of output `i` (see [`OpContext::input_loc`]).
    pub fn output_loc(&self, i: usize) -> Result<DataLoc> {
        Ok(self.locs[self.tensor_idx(&self.operator.outputs, i, "output")?])
    }

    fn bytes_at(&self, loc: DataLoc) -> Result<&'r [u8]> {
        match loc {
            DataLoc::Const { off, len } => self
                .model_data
                .get(off..off + len)
                .ok_or_else(|| Error::InvalidTensor("const range out of bounds".into())),
            DataLoc::Arena { off, len } => {
                if off + len > self.arena_len {
                    return Err(Error::InvalidTensor("arena range out of bounds".into()));
                }
                // SAFETY: range is inside the arena; see type-level invariants.
                Ok(unsafe { std::slice::from_raw_parts(self.arena.add(off), len) })
            }
        }
    }

    fn bytes_at_mut(&self, loc: DataLoc) -> Result<&'r mut [u8]> {
        match loc {
            DataLoc::Const { .. } => {
                Err(Error::InvalidTensor("cannot mutably access constant tensor".into()))
            }
            DataLoc::Arena { off, len } => {
                if off + len > self.arena_len {
                    return Err(Error::InvalidTensor("arena range out of bounds".into()));
                }
                // SAFETY: range is inside the arena and disjoint from every
                // other live tensor per the verified memory plan.
                Ok(unsafe { std::slice::from_raw_parts_mut(self.arena.add(off), len) })
            }
        }
    }

    /// Raw bytes of input `i`.
    pub fn input_bytes(&self, i: usize) -> Result<&'r [u8]> {
        let t = self.tensor_idx(&self.operator.inputs, i, "input")?;
        self.bytes_at(self.locs[t])
    }

    /// Raw mutable bytes of output `i`.
    pub fn output_bytes(&self, i: usize) -> Result<&'r mut [u8]> {
        let t = self.tensor_idx(&self.operator.outputs, i, "output")?;
        self.bytes_at_mut(self.locs[t])
    }

    /// Typed input slice.
    pub fn input_i8(&self, i: usize) -> Result<&'r [i8]> {
        self.check_dtype(self.input(i)?, DType::I8, "input", i)?;
        Ok(cast_i8(self.input_bytes(i)?))
    }

    /// Typed input slice.
    pub fn input_f32(&self, i: usize) -> Result<&'r [f32]> {
        self.check_dtype(self.input(i)?, DType::F32, "input", i)?;
        cast_f32(self.input_bytes(i)?)
    }

    /// Typed input slice.
    pub fn input_i32(&self, i: usize) -> Result<&'r [i32]> {
        self.check_dtype(self.input(i)?, DType::I32, "input", i)?;
        cast_i32(self.input_bytes(i)?)
    }

    /// Typed output slice.
    pub fn output_i8(&self, i: usize) -> Result<&'r mut [i8]> {
        self.check_dtype(self.output(i)?, DType::I8, "output", i)?;
        Ok(cast_i8_mut(self.output_bytes(i)?))
    }

    /// Typed output slice.
    pub fn output_f32(&self, i: usize) -> Result<&'r mut [f32]> {
        self.check_dtype(self.output(i)?, DType::F32, "output", i)?;
        cast_f32_mut(self.output_bytes(i)?)
    }

    /// Typed output slice.
    pub fn output_i32(&self, i: usize) -> Result<&'r mut [i32]> {
        self.check_dtype(self.output(i)?, DType::I32, "output", i)?;
        cast_i32_mut(self.output_bytes(i)?)
    }

    fn check_dtype(&self, meta: &TensorMeta, want: DType, what: &str, i: usize) -> Result<()> {
        if meta.dtype != want {
            return Err(Error::ShapeMismatch(format!(
                "op #{} ({}): {what} {i} is {}, kernel expects {}",
                self.op_index,
                self.operator.key(),
                meta.dtype,
                want
            )));
        }
        Ok(())
    }

    /// Scratch buffer requested during prepare.
    pub fn scratch_bytes(&self, h: ScratchHandle) -> Result<&'r mut [u8]> {
        let &(off, len) = self
            .scratch
            .get(h.0)
            .ok_or_else(|| Error::InvalidTensor(format!("scratch handle {} out of range", h.0)))?;
        self.bytes_at_mut(DataLoc::Arena { off, len })
    }

    /// Bounds-checked (offset, len) of persistent buffer `h`.
    fn persistent_range(&self, h: PersistentHandle) -> Result<(usize, usize)> {
        let &(off, len) = self.persistent.get(h.0).ok_or_else(|| {
            Error::InvalidTensor(format!("persistent handle {} out of range", h.0))
        })?;
        if off + len > self.persist_len {
            return Err(Error::InvalidTensor("persistent range out of bounds".into()));
        }
        Ok((off, len))
    }

    /// Persistent buffer requested during prepare, mutable for filling.
    /// Only legal during the populate pass ([`Kernel::populate`]) — at
    /// invoke time the persistent region may be shared read-only across
    /// workers (one `Arc<PreparedModel>`, many `ExecState`s), so handing
    /// out `&mut` there would alias; use [`OpContext::persistent_ro`] /
    /// [`OpContext::persistent_i8`] / [`OpContext::persistent_i32`]
    /// instead.
    ///
    /// Resolved against the persistent region, which is the arena itself
    /// for `MicroInterpreter` and a separate shared buffer for
    /// [`crate::interpreter::PreparedModel`].
    pub fn persistent_bytes(&self, h: PersistentHandle) -> Result<&'r mut [u8]> {
        if !self.populate_phase {
            return Err(Error::InvalidTensor(
                "mutable persistent access outside the populate pass".into(),
            ));
        }
        let (off, len) = self.persistent_range(h)?;
        // SAFETY: range is inside the persistent region and disjoint from
        // every other op's buffers per the bump layout. The populate-phase
        // gate above guarantees the region is not yet shared: populate
        // runs single-threaded before the model is handed to any worker,
        // so this is the only reference to these bytes.
        Ok(unsafe { std::slice::from_raw_parts_mut(self.persist_base.add(off), len) })
    }

    /// Read-only view of persistent buffer `h` (the invoke-time path).
    /// Safe to call from any number of threads sharing one
    /// `Arc<PreparedModel>`: only shared references are materialized.
    pub fn persistent_ro(&self, h: PersistentHandle) -> Result<&'r [u8]> {
        let (off, len) = self.persistent_range(h)?;
        // SAFETY: range is inside the persistent region and disjoint from
        // every other op's buffers per the bump layout. Persistent buffers
        // are written only during the single-threaded populate pass (see
        // `persistent_bytes`), so at invoke time these bytes are immutable
        // and a shared view never coexists with a mutable one.
        Ok(unsafe { std::slice::from_raw_parts(self.persist_base.add(off) as *const u8, len) })
    }

    /// Persistent buffer viewed as i8 (packed-weight layouts).
    pub fn persistent_i8(&self, h: PersistentHandle) -> Result<&'r [i8]> {
        Ok(cast_i8(self.persistent_ro(h)?))
    }

    /// Persistent buffer viewed as i32 (folded-bias tables).
    pub fn persistent_i32(&self, h: PersistentHandle) -> Result<&'r [i32]> {
        cast_i32(self.persistent_ro(h)?)
    }

    /// Convenience: error with this op's identity attached.
    pub fn fail(&self, reason: impl Into<String>) -> Error {
        Error::InvokeFailed {
            op_index: self.op_index,
            op_name: self.operator.opcode.name(),
            reason: reason.into(),
        }
    }

    /// Init-time variant of [`fail`]: populate-pass errors happen during
    /// interpreter construction, so they report as prepare failures, not
    /// invoke failures.
    ///
    /// [`fail`]: OpContext::fail
    pub fn fail_init(&self, reason: impl Into<String>) -> Error {
        Error::PrepareFailed {
            op_index: self.op_index,
            op_name: self.operator.opcode.name(),
            reason: reason.into(),
        }
    }
}

// ---- checked byte <-> typed-slice casts -------------------------------

/// Reinterpret bytes as i8 (always valid).
pub fn cast_i8(b: &[u8]) -> &[i8] {
    // SAFETY: i8 and u8 have identical layout.
    unsafe { std::slice::from_raw_parts(b.as_ptr() as *const i8, b.len()) }
}

/// Reinterpret bytes as mutable i8.
pub fn cast_i8_mut(b: &mut [u8]) -> &mut [i8] {
    // SAFETY: i8 and u8 have identical layout.
    unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut i8, b.len()) }
}

macro_rules! checked_cast {
    ($name:ident, $name_mut:ident, $ty:ty) => {
        /// Reinterpret bytes as a typed slice, checking alignment and size.
        pub fn $name(b: &[u8]) -> Result<&[$ty]> {
            let size = std::mem::size_of::<$ty>();
            if b.len() % size != 0 || b.as_ptr() as usize % std::mem::align_of::<$ty>() != 0 {
                return Err(Error::ShapeMismatch(format!(
                    "byte slice (len {}, addr {:p}) cannot view as {}",
                    b.len(),
                    b.as_ptr(),
                    stringify!($ty)
                )));
            }
            // SAFETY: alignment and size checked above.
            Ok(unsafe { std::slice::from_raw_parts(b.as_ptr() as *const $ty, b.len() / size) })
        }

        /// Mutable variant of the checked cast.
        pub fn $name_mut(b: &mut [u8]) -> Result<&mut [$ty]> {
            let size = std::mem::size_of::<$ty>();
            if b.len() % size != 0 || b.as_ptr() as usize % std::mem::align_of::<$ty>() != 0 {
                return Err(Error::ShapeMismatch(format!(
                    "byte slice (len {}, addr {:p}) cannot view as {}",
                    b.len(),
                    b.as_ptr(),
                    stringify!($ty)
                )));
            }
            // SAFETY: alignment and size checked above.
            Ok(unsafe {
                std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut $ty, b.len() / size)
            })
        }
    };
}

checked_cast!(cast_f32, cast_f32_mut, f32);
checked_cast!(cast_i32, cast_i32_mut, i32);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn i8_cast_is_total() {
        let b = [0u8, 255, 128];
        let s = cast_i8(&b);
        assert_eq!(s, &[0i8, -1, -128]);
    }

    #[test]
    fn f32_cast_checks_size() {
        let v = [0u8; 9];
        assert!(cast_f32(&v[..9]).is_err()); // bad size always fails
        let fv = [1.0f32, 2.0];
        // SAFETY: viewing f32s as bytes is always valid.
        let bytes = unsafe { std::slice::from_raw_parts(fv.as_ptr() as *const u8, 8) };
        assert_eq!(cast_f32(bytes).unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn opdata_charges_conv_tables() {
        let d = OpData::Conv(common::ConvData {
            per_channel: vec![Default::default(); 8],
            ..Default::default()
        });
        assert!(d.arena_bytes() >= 64);
    }
}
