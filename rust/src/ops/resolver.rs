//! The OpResolver: maps serialized operator types to kernel
//! implementations (§4.1).
//!
//! "The application developer produces an 'operator resolver' object
//! through the client API. The OpResolver API controls which operators
//! link to the final binary, minimizing executable size." In Rust the
//! linker argument becomes: only the kernels you `register` are
//! reachable, so everything else is dead-code-eliminated from the binary.
//! The resolver has a fixed capacity set at construction, like
//! `MicroMutableOpResolver<N>`.
//!
//! Vendors swap in optimized kernels by registering a different
//! implementation for the same opcode — no interpreter change (§4.8).

use super::{Kernel, KernelFlavor};
use crate::error::{Error, Result};
use crate::schema::BuiltinOp;
use std::sync::Arc;

/// Default resolver capacity (ample for the builtin set).
pub const DEFAULT_CAPACITY: usize = 28;

/// Maps operator keys (builtin names or custom-op names) to kernels.
pub struct OpResolver {
    entries: Vec<(String, Arc<dyn Kernel>)>,
    capacity: usize,
}

impl OpResolver {
    /// Empty resolver with the default capacity.
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    /// Empty resolver bounded at `capacity` registrations.
    pub fn with_capacity(capacity: usize) -> Self {
        OpResolver { entries: Vec::with_capacity(capacity), capacity }
    }

    /// Resolver with every builtin reference kernel registered — the
    /// "kitchen sink" (`AllOpsResolver` in TF Micro). Production
    /// deployments should register only what their model needs.
    pub fn with_reference_ops() -> Self {
        let mut r = Self::with_capacity(BuiltinOp::ALL.len());
        super::ref_ops::register_all(&mut r).expect("capacity sized for all builtins");
        r
    }

    /// Resolver preferring optimized kernels, falling back to reference
    /// implementations for ops without an optimized version — exactly how
    /// a CMSIS-NN build composes (§4.8).
    pub fn with_optimized_ops() -> Self {
        let mut r = Self::with_capacity(BuiltinOp::ALL.len());
        super::ref_ops::register_all(&mut r).expect("capacity sized for all builtins");
        super::opt_ops::register_all(&mut r).expect("re-registration needs no capacity");
        r
    }

    /// Register a kernel for a builtin op. Re-registering an op replaces
    /// the previous kernel (that is the vendor-override mechanism).
    pub fn register(&mut self, op: BuiltinOp, kernel: Arc<dyn Kernel>) -> Result<()> {
        self.register_key(op.name(), kernel)
    }

    /// Register a kernel for a custom op name.
    pub fn register_custom(&mut self, name: &str, kernel: Arc<dyn Kernel>) -> Result<()> {
        self.register_key(name, kernel)
    }

    fn register_key(&mut self, key: &str, kernel: Arc<dyn Kernel>) -> Result<()> {
        if let Some(slot) = self.entries.iter_mut().find(|(k, _)| k == key) {
            slot.1 = kernel;
            return Ok(());
        }
        if self.entries.len() >= self.capacity {
            return Err(Error::ResolverFull(self.capacity));
        }
        self.entries.push((key.to_string(), kernel));
        Ok(())
    }

    /// Look up the kernel for an operator key.
    pub fn find(&self, key: &str) -> Result<&dyn Kernel> {
        self.entries
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_ref())
            .ok_or_else(|| Error::UnsupportedOp(key.to_string()))
    }

    /// Look up the kernel for an operator key as an owning handle.
    ///
    /// [`crate::interpreter::PreparedModel`] clones the `Arc` so the
    /// prepared state (and the serving registry's live versions built on
    /// it) stays valid independently of the resolver's lifetime — the
    /// resolver is a build-time object, a published model version is not.
    pub fn find_arc(&self, key: &str) -> Result<Arc<dyn Kernel>> {
        self.entries
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| Arc::clone(v))
            .ok_or_else(|| Error::UnsupportedOp(key.to_string()))
    }

    /// Flavor of the registered kernel for `key` (bench introspection).
    pub fn flavor_of(&self, key: &str) -> Option<KernelFlavor> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v.flavor())
    }

    /// Number of registered kernels.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Default for OpResolver {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{OpContext, PrepareContext};

    struct NopKernel(KernelFlavor);
    impl Kernel for NopKernel {
        fn flavor(&self) -> KernelFlavor {
            self.0
        }
        fn prepare(&self, _: &mut PrepareContext) -> Result<()> {
            Ok(())
        }
        fn invoke(&self, _: &OpContext) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn register_and_find() {
        let mut r = OpResolver::with_capacity(2);
        r.register(BuiltinOp::Relu, Arc::new(NopKernel(KernelFlavor::Reference))).unwrap();
        assert!(r.find("RELU").is_ok());
        assert!(matches!(r.find("CONV_2D"), Err(Error::UnsupportedOp(_))));
    }

    #[test]
    fn capacity_enforced() {
        let mut r = OpResolver::with_capacity(1);
        r.register(BuiltinOp::Relu, Arc::new(NopKernel(KernelFlavor::Reference))).unwrap();
        let err = r.register(BuiltinOp::Relu6, Arc::new(NopKernel(KernelFlavor::Reference)));
        assert!(matches!(err, Err(Error::ResolverFull(1))));
    }

    #[test]
    fn reregistration_overrides_without_capacity() {
        let mut r = OpResolver::with_capacity(1);
        r.register(BuiltinOp::Conv2d, Arc::new(NopKernel(KernelFlavor::Reference))).unwrap();
        assert_eq!(r.flavor_of("CONV_2D"), Some(KernelFlavor::Reference));
        // Vendor override: same op, optimized kernel, still capacity 1.
        r.register(BuiltinOp::Conv2d, Arc::new(NopKernel(KernelFlavor::Optimized))).unwrap();
        assert_eq!(r.flavor_of("CONV_2D"), Some(KernelFlavor::Optimized));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn custom_ops_resolved_by_name() {
        let mut r = OpResolver::with_capacity(2);
        r.register_custom("MY_VENDOR_OP", Arc::new(NopKernel(KernelFlavor::Accelerated)))
            .unwrap();
        assert!(r.find("MY_VENDOR_OP").is_ok());
        assert_eq!(r.flavor_of("MY_VENDOR_OP"), Some(KernelFlavor::Accelerated));
    }

    #[test]
    fn full_reference_resolver_covers_all_builtins() {
        let r = OpResolver::with_reference_ops();
        for op in BuiltinOp::ALL {
            assert!(r.find(op.name()).is_ok(), "missing reference kernel for {}", op.name());
        }
    }

    #[test]
    fn optimized_resolver_prefers_optimized_conv() {
        let r = OpResolver::with_optimized_ops();
        assert_eq!(r.flavor_of("CONV_2D"), Some(KernelFlavor::Optimized));
        // Ops without an optimized version keep the reference kernel.
        assert_eq!(r.flavor_of("RESHAPE"), Some(KernelFlavor::Reference));
    }
}
