//! `tfmicro` CLI — run, inspect, benchmark, and serve TMF models.
fn main() {
    tfmicro::cli_main();
}
