//! `tfmicro` command-line interface.
//!
//! Hand-rolled argument parsing (no clap in the offline registry —
//! DESIGN.md §6.6; also in the spirit of §3.1's minimal dependencies).
//!
//! ```text
//! tfmicro inspect  <model.tmf>
//! tfmicro run      <model.tmf> [--kernels ref|opt] [--iters N] [--profile] [--arena-kb N]
//! tfmicro opt      <model.tmf> [--kernels ref|opt]
//! tfmicro mem      <model.tmf> [--planner greedy|linear|auto]
//! tfmicro overhead <model.tmf> [--kernels ref|opt] [--iters N]
//! tfmicro simulate <model.tmf> [--platform m4|dsp]
//! tfmicro serve    <model.tmf> [--workers N] [--requests N] [--reload <model.tmf>]
//! tfmicro cpu
//! tfmicro lint     [--root DIR] [--json] [--deny-warnings]
//! ```

use crate::error::{Error, Result};
use crate::interpreter::{MicroInterpreter, Options, PlannerChoice};
use crate::ops::{KernelFlavor, OpResolver};
use crate::platform::{simulate, Platform};
use crate::profiler::{measure_overhead, MicroProfiler};
use crate::schema::Model;
use crate::serving::{
    make_requests, run_closed_loop, run_registry_with_feeder, CanaryConfig, ModelRegistry,
    ServingConfig,
};
use crate::testutil::{fmt_kb, fmt_kcycles, Rng};

/// Tiny flag parser: positional args + `--key value` / `--flag`.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let value = argv.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if value.is_some() {
                    i += 1;
                }
                flags.push((name.to_string(), value));
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().find(|(n, _)| n == name).and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn resolver_for(kind: Option<&str>) -> Result<OpResolver> {
    match kind.unwrap_or("opt") {
        "ref" | "reference" => Ok(OpResolver::with_reference_ops()),
        "opt" | "optimized" => Ok(OpResolver::with_optimized_ops()),
        other => Err(Error::Serving(format!("unknown kernel family '{other}' (ref|opt)"))),
    }
}

fn load(path: &str) -> Result<Model> {
    Model::from_file(path)
}

fn fill_random_input(interp: &mut MicroInterpreter, seed: u64) -> Result<()> {
    let mut rng = Rng::seeded(seed);
    let mut view = interp.input_mut(0)?;
    match view.meta.dtype {
        crate::tensor::DType::I8 => {
            for v in view.as_i8_mut()? {
                *v = rng.next_i8();
            }
        }
        crate::tensor::DType::F32 => {
            for v in view.as_f32_mut()? {
                *v = rng.range_f32(-1.0, 1.0);
            }
        }
        other => return Err(Error::Serving(format!("unsupported input dtype {other}"))),
    }
    Ok(())
}

const USAGE: &str = "usage: tfmicro <inspect|run|opt|mem|overhead|simulate|serve|cpu|lint> <model.tmf> [flags]
  inspect   print model structure
  run       execute with random inputs (--kernels ref|opt, --iters N, --profile, --arena-kb N)
  opt       prepare-time graph rewriter report: pass-by-pass rewrite log
            plus the activation-plan delta (--kernels ref|opt picks the
            resolver the fuse pass consults)
  mem       arena accounting, Table 2 style, with per-rewrite-pass arena
            attribution (--planner greedy|linear|auto, --kernels ref|opt)
  overhead  measured interpreter overhead, Figure 6 methodology (--iters N)
  simulate  cycle-model Figure 6 row (--platform m4|dsp)
  serve     closed-loop serving demo (--workers N, --requests N, --arena-kb N,
            --max-respawns N, --deadline-ms N, --reload <model.tmf> to hot-swap
            a second model mid-run through the canary lifecycle)
  cpu       detected CPU features + chosen kernel dispatch (no model needed)
  lint      self-hosted invariant checker over the crate's own sources
            (--root DIR to lint another checkout, --json for one diagnostic
            per line, --deny-warnings to fail on warnings too; no model
            needed)";

/// `tfmicro cpu`: field debugging for "why is this slow here" — what the
/// runtime feature probes saw and which kernel tiers this process runs.
fn print_cpu_report() {
    use crate::ops::opt_ops::{depthwise, depthwise::DW_CH_BLOCK, gemm};
    println!("arch: {}", std::env::consts::ARCH);
    #[cfg(target_arch = "x86_64")]
    {
        let f = |b: bool| if b { "yes" } else { "no" };
        println!(
            "features: avx2={} ssse3={} sse4.1={}",
            f(std::arch::is_x86_feature_detected!("avx2")),
            f(std::arch::is_x86_feature_detected!("ssse3")),
            f(std::arch::is_x86_feature_detected!("sse4.1")),
        );
        #[cfg(tfmicro_dotprod_tiers)]
        println!(
            "dot-product: avxvnni={} avx512vnni={} avx512vl={}",
            f(std::arch::is_x86_feature_detected!("avxvnni")),
            f(std::arch::is_x86_feature_detected!("avx512vnni")),
            f(std::arch::is_x86_feature_detected!("avx512vl")),
        );
        #[cfg(not(tfmicro_dotprod_tiers))]
        println!("dot-product: (probes need rustc >= 1.89; tier compiled out)");
    }
    #[cfg(target_arch = "aarch64")]
    {
        let f = |b: bool| if b { "yes" } else { "no" };
        println!(
            "features: neon={}",
            f(std::arch::is_aarch64_feature_detected!("neon")),
        );
        #[cfg(tfmicro_dotprod_tiers)]
        println!(
            "dot-product: dotprod={}",
            f(std::arch::is_aarch64_feature_detected!("dotprod")),
        );
        #[cfg(not(tfmicro_dotprod_tiers))]
        println!("dot-product: (probes need rustc >= 1.89; tier compiled out)");
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        println!("features: (no SIMD feature probes compiled for this arch)");
    }
    let backends: Vec<String> = gemm::GemmBackend::all()
        .into_iter()
        .map(|b| format!("{}={}", b.name(), if b.available() { "ok" } else { "unavailable" }))
        .collect();
    println!("gemm backends: {}", backends.join(" "));
    println!(
        "gemm dispatch: {}{}",
        gemm::active_backend().name(),
        if gemm::dispatch_is_forced() { " (forced)" } else { " (auto, cached at first use)" },
    );
    println!(
        "depthwise: channel-blocked x{DW_CH_BLOCK} interior, dispatched body: {} \
         (keyed by the gemm backend) + scalar ragged edge/border",
        depthwise::dw_interior_name(),
    );
}

/// `tfmicro lint`: run the invariant checks (see [`crate::analysis`])
/// over a source tree — by default the tree this binary was built from,
/// so `cargo run -- lint` in a checkout checks that checkout.
fn run_lint_report(args: &Args) -> Result<()> {
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        // Prefer the current directory when it looks like a checkout
        // (an installed binary may outlive its build tree); fall back
        // to the tree recorded at compile time.
        None if std::path::Path::new("rust/src").is_dir() => std::path::PathBuf::from("."),
        None => std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")),
    };
    let diags = crate::analysis::lint_root(&root).map_err(Error::Serving)?;
    let json = args.has("json");
    let errors = diags
        .iter()
        .filter(|d| d.severity == crate::analysis::Severity::Error)
        .count();
    let warnings = diags.len() - errors;
    for d in &diags {
        if json {
            println!("{}", d.render_json());
        } else {
            println!("{}", d.render());
        }
    }
    if errors > 0 || (args.has("deny-warnings") && warnings > 0) {
        return Err(Error::Serving(format!(
            "lint: {errors} error(s), {warnings} warning(s)"
        )));
    }
    if !json {
        println!("lint: clean ({warnings} warning(s))");
    }
    Ok(())
}

/// CLI entry; returns a process exit code.
pub fn main_with_args(argv: Vec<String>) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let Some(cmd) = argv.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    // `cpu` inspects the process, not a model — no path required.
    if cmd == "cpu" {
        print_cpu_report();
        return Ok(());
    }
    // `lint` inspects the source tree, not a model — no path required.
    if cmd == "lint" {
        return run_lint_report(&Args::parse(&argv[1..]));
    }
    let args = Args::parse(&argv[1..]);
    let model_path = args
        .positional
        .first()
        .ok_or_else(|| Error::Serving(format!("missing model path\n{USAGE}")))?;

    match cmd.as_str() {
        "inspect" => {
            let model = load(model_path)?;
            println!("model: {} ({} bytes serialized)", model.description(), model.serialized_size());
            println!("tensors: {}   operators: {}", model.tensors().len(), model.operators().len());
            println!("inputs: {:?}   outputs: {:?}", model.inputs(), model.outputs());
            for (i, op) in model.operators().iter().enumerate() {
                println!("  #{i:<3} {:<20} in={:?} out={:?}", op.key(), op.inputs, op.outputs);
            }
            for (i, t) in model.tensors().iter().enumerate() {
                let kind = if t.buffer.is_some() { "const" } else if t.is_variable { "var" } else { "act" };
                println!("  t{i:<3} {:<24} {} {} {}", t.name, t.dtype, t.shape, kind);
            }
            if model.offline_plan().is_some() {
                println!("carries an offline memory plan");
            }
        }
        "run" => {
            let model = load(model_path)?;
            let resolver = resolver_for(args.get("kernels"))?;
            let mut arena = crate::arena::Arena::new(args.usize_or("arena-kb", 512) * 1024);
            let mut interp = MicroInterpreter::new(&model, &resolver, &mut arena)?;
            fill_random_input(&mut interp, 42)?;
            let iters = args.usize_or("iters", 10);
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                interp.invoke()?;
            }
            let per = t0.elapsed() / iters as u32;
            println!("{iters} invocations, {per:.3?} each");
            if args.has("profile") {
                let mut prof = MicroProfiler::new();
                interp.invoke_observed(&mut prof)?;
                print!("{}", prof.report());
            }
            let out = interp.output(0)?;
            match out.meta.dtype {
                crate::tensor::DType::I8 => println!("output[0] = {:?}", &out.as_i8()?[..out.as_i8()?.len().min(16)]),
                crate::tensor::DType::F32 => println!("output[0] = {:?}", &out.as_f32()?[..out.as_f32()?.len().min(16)]),
                _ => {}
            }
        }
        "opt" => {
            use crate::planner::{analyze_lifetimes, GreedyPlanner, MemoryPlanner};
            use crate::rewriter::{self, RewriteOutcome};

            let model = load(model_path)?;
            let resolver = resolver_for(args.get("kernels"))?;
            println!("model: {}", model.description());
            match rewriter::rewrite(&model, Some(&resolver))? {
                RewriteOutcome::Unchanged => {
                    println!("no rewrite fired: the graph is already in lowered form, \
                              carries rewrite metadata, or opted out");
                }
                RewriteOutcome::Rewritten { model: optimized, log } => {
                    println!("ops:     {} -> {}", log.ops_before, log.ops_after);
                    println!("tensors: {} -> {}", log.tensors_before, log.tensors_after);
                    for p in &log.passes {
                        let fired =
                            p.ops_removed + p.tensors_removed + p.fused + p.aliased > 0;
                        if fired {
                            println!(
                                "pass {:<13} ops -{}, tensors -{}, fused {}, aliased {}",
                                p.name, p.ops_removed, p.tensors_removed, p.fused, p.aliased
                            );
                        } else {
                            println!("pass {:<13} (no-op)", p.name);
                        }
                        for d in &p.details {
                            println!("    {d}");
                        }
                    }
                    let bytes = |m: &Model| -> Result<usize> {
                        let info = analyze_lifetimes(m)?;
                        Ok(GreedyPlanner
                            .plan(&info.requests, crate::arena::DEFAULT_ALIGN)?
                            .arena_size)
                    };
                    let (before, after) = (bytes(&model)?, bytes(&optimized)?);
                    println!(
                        "activation plan (greedy): {} -> {} ({} saved)",
                        fmt_kb(before),
                        fmt_kb(after),
                        fmt_kb(before.saturating_sub(after)),
                    );
                }
            }
        }
        "mem" => {
            let model = load(model_path)?;
            let resolver = resolver_for(args.get("kernels"))?;
            let planner = match args.get("planner").unwrap_or("greedy") {
                "greedy" => PlannerChoice::Greedy,
                "linear" => PlannerChoice::Linear,
                "auto" => PlannerChoice::Auto,
                "offline" => PlannerChoice::Offline,
                other => return Err(Error::Serving(format!("unknown planner '{other}'"))),
            };
            let mut arena = crate::arena::Arena::new(args.usize_or("arena-kb", 2048) * 1024);
            let interp = MicroInterpreter::with_options(
                &model,
                &resolver,
                arena.as_mut_slice(),
                Options { planner, ..Default::default() },
            )?;
            let u = interp.arena_usage();
            println!("model: {}", model.description());
            println!("persistent:    {}", fmt_kb(u.persistent));
            println!("  kernel bufs: {}", fmt_kb(u.kernel_buffers));
            println!("nonpersistent: {}", fmt_kb(u.nonpersistent));
            println!("total:         {}", fmt_kb(u.total));
            println!("flash (model): {}", fmt_kb(model.serialized_size()));
            if args.has("detail") {
                println!("{}", interp.arena_usage_detail().report());
            }
            // Per-pass arena attribution: replan the activation region
            // after each rewrite-pass prefix so each pass's saving is
            // visible on its own. Offline plans pin offsets against the
            // unrewritten tensor table, so attribution is moot there.
            if !matches!(planner, PlannerChoice::Offline) {
                use crate::planner::{
                    analyze_lifetimes, GreedyPlanner, LinearPlanner, MemoryPlanner,
                };
                use crate::rewriter::{self, RewriteOutcome, PASS_NAMES};

                let plan_bytes = |m: &Model| -> Result<usize> {
                    let info = analyze_lifetimes(m)?;
                    let plan = if matches!(planner, PlannerChoice::Linear) {
                        LinearPlanner.plan(&info.requests, crate::arena::DEFAULT_ALIGN)?
                    } else {
                        GreedyPlanner.plan(&info.requests, crate::arena::DEFAULT_ALIGN)?
                    };
                    Ok(plan.arena_size)
                };
                let base = plan_bytes(&model)?;
                println!("rewrite-pass arena attribution (activation plan):");
                println!("  (no rewrite)     {}", fmt_kb(base));
                let mut prev = base;
                for n in 1..=PASS_NAMES.len() {
                    let bytes = match rewriter::rewrite_prefix(&model, Some(&resolver), n)? {
                        RewriteOutcome::Unchanged => base,
                        RewriteOutcome::Rewritten { model: m, .. } => plan_bytes(&m)?,
                    };
                    let saved = prev.saturating_sub(bytes);
                    println!(
                        "  + {:<14} {} ({} saved by this pass)",
                        PASS_NAMES[n - 1],
                        fmt_kb(bytes),
                        fmt_kb(saved),
                    );
                    prev = bytes;
                }
            }
        }
        "overhead" => {
            let model = load(model_path)?;
            let resolver = resolver_for(args.get("kernels"))?;
            let mut arena = crate::arena::Arena::new(args.usize_or("arena-kb", 512) * 1024);
            let mut interp = MicroInterpreter::new(&model, &resolver, &mut arena)?;
            fill_random_input(&mut interp, 42)?;
            let rep = measure_overhead(&mut interp, args.usize_or("iters", 30))?;
            println!("total:       {:?}", rep.total);
            println!("calculation: {:?}", rep.calculation);
            println!("overhead:    {:?} ({:.2}%)", rep.overhead, rep.overhead_pct);
        }
        "simulate" => {
            let model = load(model_path)?;
            let platform = match args.get("platform").unwrap_or("m4") {
                "m4" | "cortex-m4" => Platform::cortex_m4_like(),
                "dsp" | "hifi" => Platform::hifi_mini_like(),
                other => return Err(Error::Serving(format!("unknown platform '{other}'"))),
            };
            println!("platform: {} ({}, {} MHz)", platform.name, platform.processor, platform.clock_hz / 1_000_000);
            for (label, flavor) in [("reference", KernelFlavor::Reference), ("optimized", KernelFlavor::Optimized)] {
                let r = simulate(&model, flavor, &platform);
                println!(
                    "{label:<10} total {:>12}  calc {:>12}  overhead {}  ({:.1} ms)",
                    fmt_kcycles(r.total_cycles),
                    fmt_kcycles(r.calc_cycles),
                    if r.overhead_pct < 0.1 { "< 0.1%".to_string() } else { format!("{:.1}%", r.overhead_pct) },
                    r.wall_ms,
                );
            }
        }
        "serve" => {
            let model = load(model_path)?;
            let resolver = resolver_for(args.get("kernels"))?;
            let in_len = model.tensors()[model.inputs()[0] as usize].num_elements();
            let out_len = model.tensors()[model.outputs()[0] as usize].num_elements();
            let cfg = ServingConfig {
                workers: args.usize_or("workers", 2),
                queue_depth: args.usize_or("queue", 32),
                arena_bytes: args.usize_or("arena-kb", 512) * 1024,
                max_respawns: args.usize_or("max-respawns", 4),
                default_deadline: args
                    .get("deadline-ms")
                    .and_then(|v| v.parse::<u64>().ok())
                    .map(std::time::Duration::from_millis),
                ..Default::default()
            };
            let n = args.usize_or("requests", 256);
            let mut rng = Rng::seeded(7);
            let mut requests = make_requests(n, |_| {
                let mut v = vec![0i8; in_len];
                rng.fill_i8(&mut v);
                v
            });
            let report = if let Some(reload_path) = args.get("reload") {
                // Zero-downtime lifecycle demo: serve v1, then publish the
                // reload file as v2 mid-run (prepare + canary off the hot
                // path, atomic swap at the workers' next queue pull).
                let reload = std::sync::Arc::new(load(reload_path)?);
                let registry = ModelRegistry::new();
                registry.publish(
                    "v1",
                    std::sync::Arc::new(model),
                    &resolver,
                    &CanaryConfig::default(),
                )?;
                let rest = requests.split_off(n / 2);
                // The reload is typically a *different* model, so bit-exact
                // shadow comparison against v1 would (correctly) reject it;
                // health is carried by the shadow invokes themselves.
                let reload_canary =
                    CanaryConfig { require_bit_exact: false, ..CanaryConfig::default() };
                // The reload gets its own resolver: kernels that key
                // staged state by op index (the registry module's sharing
                // caveat, e.g. an XLA registration) would otherwise have
                // v2's populate clobber v1's state, silently degrading the
                // still-live v1 — and any rollback to it — for the rest of
                // the run.
                let reload_resolver = resolver_for(args.get("kernels"))?;
                let registry_ref = &registry;
                let resolver_ref = &reload_resolver;
                run_registry_with_feeder(
                    &registry,
                    cfg,
                    out_len,
                    move |sub| {
                        for r in requests {
                            let _ = sub.submit(r);
                        }
                        match registry_ref.publish("v2", reload, resolver_ref, &reload_canary) {
                            Ok(v) => eprintln!("hot-swapped to version '{}'", v.name()),
                            Err(e) => eprintln!("reload rejected, v1 keeps serving: {e}"),
                        }
                        for r in rest {
                            let _ = sub.submit(r);
                        }
                    },
                    |_| {},
                )?
            } else {
                run_closed_loop(&model, &resolver, cfg, requests, out_len)?
            };
            println!("{}", report.summary());
            println!("per-worker: {:?}", report.per_worker);
            // Error taxonomy: always printed so a clean run is visibly
            // clean and a degraded one says exactly what was contained.
            println!("faults: {}", report.faults.summary());
            println!(
                "breaker: {}",
                if report.breaker_open { "OPEN (respawn budget exhausted)" } else { "closed" }
            );
            println!(
                "cold start (first-request latency per worker): {:?}",
                report
                    .cold_start_ns
                    .iter()
                    .map(|&ns| std::time::Duration::from_nanos(ns))
                    .collect::<Vec<_>>()
            );
            if let Some(v) = &report.active_version {
                println!("active version: {v}");
            }
        }
        other => {
            return Err(Error::Serving(format!("unknown command '{other}'\n{USAGE}")));
        }
    }
    Ok(())
}

/// Entrypoint used by `rust/src/main.rs`.
pub fn cli_main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(main_with_args(argv));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parsing() {
        let argv: Vec<String> =
            ["model.tmf", "--iters", "5", "--profile", "--kernels", "ref"].iter().map(|s| s.to_string()).collect();
        let a = Args::parse(&argv);
        assert_eq!(a.positional, vec!["model.tmf"]);
        assert_eq!(a.usize_or("iters", 1), 5);
        assert!(a.has("profile"));
        assert_eq!(a.get("kernels"), Some("ref"));
        assert_eq!(a.usize_or("missing", 9), 9);
    }

    #[test]
    fn unknown_command_errors() {
        assert_eq!(main_with_args(vec!["bogus".into(), "x.tmf".into()]), 1);
    }

    #[test]
    fn no_args_prints_usage() {
        assert_eq!(main_with_args(vec![]), 0);
    }

    #[test]
    fn cpu_subcommand_needs_no_model() {
        assert_eq!(main_with_args(vec!["cpu".into()]), 0);
    }
}
