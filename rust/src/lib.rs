//! # tfmicro — an interpreter-based TinyML inference framework
//!
//! A Rust reproduction of *TensorFlow Lite Micro: Embedded Machine Learning
//! on TinyML Systems* (David et al., 2020). The crate provides the complete
//! framework the paper describes:
//!
//! * a portable, zero-copy serialized model format ([`schema`], the
//!   FlatBuffer-schema analog — "TMF"),
//! * static memory management from a caller-supplied arena with a
//!   two-stack allocator ([`arena`], paper §4.4.1 / Figure 3),
//! * a greedy bin-packing memory planner for intermediate tensors plus a
//!   naive baseline and an offline-planned mode ([`planner`], §4.4.2 /
//!   Figure 4),
//! * an operator registry with an `OpResolver` that links only the kernels
//!   a model needs, and reference vs. platform-optimized kernel variants
//!   ([`ops`], §4.1/§4.7/§4.8),
//! * the interpreter itself — allocate once, then `invoke()` with no
//!   further allocation ([`interpreter`], §4.1/§4.2),
//! * multitenancy over a shared arena (§4.5 / Figure 5),
//! * profiling hooks and simulated embedded-platform cycle models
//!   ([`profiler`], [`platform`], §5),
//! * a prepare-time graph rewriter that folds pads, elides no-op views,
//!   and fuses requant epilogues before planning ([`rewriter`]),
//! * an XLA/PJRT runtime that loads AOT-compiled JAX/Pallas kernels as the
//!   "vendor optimized library" path ([`runtime`]),
//! * and a small std-only serving layer used by the end-to-end examples
//!   ([`serving`]),
//! * plus a self-hosted invariant checker (`tfmicro lint`) that
//!   statically enforces the crate's no-panic / unsafe-confinement /
//!   fault-point / lock-order contracts over its own sources
//!   ([`analysis`]).
//!
//! ## Quickstart
//!
//! ```no_run
//! use tfmicro::prelude::*;
//!
//! let bytes = std::fs::read("artifacts/conv_ref.tmf").unwrap();
//! let model = Model::from_bytes(&bytes).unwrap();
//! let resolver = OpResolver::with_reference_ops();
//! let mut arena = Arena::new(64 * 1024);
//! let mut interp = MicroInterpreter::new(&model, &resolver, &mut arena).unwrap();
//! interp.input_mut(0).unwrap().fill_i8(0);
//! interp.invoke().unwrap();
//! let out = interp.output(0).unwrap();
//! println!("scores = {:?}", out.as_i8().unwrap());
//! ```

pub mod analysis;
pub mod arena;
pub mod cli;
pub mod error;
pub mod faults;
pub mod interpreter;
pub mod ops;
pub mod planner;
pub mod platform;
pub mod profiler;
pub mod rewriter;
pub mod runtime;
pub mod schema;
pub mod serving;
pub mod tensor;
pub mod testutil;

/// Convenient re-exports of the types most applications need.
pub mod prelude {
    pub use crate::arena::Arena;
    pub use crate::error::{Error, Result};
    pub use crate::interpreter::{ExecState, MicroInterpreter, PreparedModel};
    pub use crate::ops::resolver::OpResolver;
    pub use crate::schema::model::Model;
    pub use crate::tensor::{DType, QuantParams};
}

pub use cli::cli_main;
