//! `no_panic` — the §4.4.1 contract: the framework never crashes the
//! host. On the crash-sensitive surface below, panicking constructs are
//! forbidden outside test code; errors must be typed `Error` returns.
//!
//! Flagged: `.unwrap()`, `.expect(...)`, `panic!`, `unreachable!`,
//! `todo!`, `unimplemented!`, and `)[<integer>]` (indexing a call
//! result with a constant — an implicit bounds panic on data the
//! caller did not validate). Not flagged: `.unwrap_or_else`,
//! `.expect_err`, idents merely *containing* a token
//! (`kernel_panic_point`), and plain local indexing like `b[0]` where
//! the bounds are established by an adjacent check (the schema
//! reader's documented idiom).

use super::lexer::LexedFile;
use super::{Diagnostic, Severity};

/// Files the contract applies to (paths relative to `rust/`). The old
/// grep gate covered only the first three; this is the full
/// crash-sensitive surface: serving, registry hot-swap, flatbuffer
/// reading, prepared execution, the prepare-time graph rewriter (runs
/// on every untrusted model before planning), and the kernel invoke
/// paths.
pub const SURFACE: &[&str] = &[
    "src/serving/mod.rs",
    "src/serving/batch.rs",
    "src/serving/registry.rs",
    "src/schema/reader.rs",
    "src/interpreter/prepared.rs",
    "src/rewriter/mod.rs",
    "src/ops/opt_ops/conv.rs",
    "src/ops/opt_ops/fully_connected.rs",
    "src/ops/opt_ops/gemm/mod.rs",
    "src/ops/opt_ops/gemm/scalar.rs",
    "src/ops/opt_ops/depthwise/mod.rs",
    "src/ops/opt_ops/depthwise/scalar.rs",
    "src/runtime/mod.rs",
    "src/runtime/xla_kernel.rs",
];

const METHODS: &[&str] = &["unwrap", "expect"];
const MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

pub fn check(f: &LexedFile, diags: &mut Vec<Diagnostic>) {
    if !SURFACE.contains(&f.rel_path.as_str()) {
        return;
    }
    let text = f.scrubbed_nontest();
    let ch: Vec<char> = text.chars().collect();
    let n = ch.len();
    let mut line = 1usize;
    let mut i = 0usize;
    let mut emit = |line: usize, msg: String| {
        diags.push(Diagnostic {
            file: f.display_path.clone(),
            line,
            check: "no_panic",
            message: msg,
            severity: Severity::Error,
        });
    };
    while i < n {
        let c = ch[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        // `)[<integer>]` — indexing a call result with a constant.
        if c == ')' && i + 1 < n && ch[i + 1] == '[' {
            let mut j = i + 2;
            while j < n && ch[j].is_whitespace() && ch[j] != '\n' {
                j += 1;
            }
            let d0 = j;
            while j < n && ch[j].is_ascii_digit() {
                j += 1;
            }
            if j > d0 {
                let mut k = j;
                while k < n && ch[k].is_whitespace() && ch[k] != '\n' {
                    k += 1;
                }
                if k < n && ch[k] == ']' {
                    emit(
                        line,
                        "indexing a call result with a constant (`)[N]`) can panic on \
                         short input; use .get()/.first() and return a typed error"
                            .to_string(),
                    );
                    i = k + 1;
                    continue;
                }
            }
        }
        if is_ident(c) && (i == 0 || !is_ident(ch[i - 1])) {
            let s = i;
            let mut j = i;
            while j < n && is_ident(ch[j]) {
                j += 1;
            }
            let word: String = ch[s..j].iter().collect();
            let prev_dot = s > 0 && ch[s - 1] == '.';
            if prev_dot && METHODS.contains(&word.as_str()) {
                emit(
                    line,
                    format!(
                        ".{}() is forbidden on the no-panic surface; \
                         return a typed Error instead",
                        word
                    ),
                );
            } else if MACROS.contains(&word.as_str()) && j < n && ch[j] == '!' {
                emit(
                    line,
                    format!(
                        "{}! is forbidden on the no-panic surface; \
                         return a typed Error instead",
                        word
                    ),
                );
            }
            i = j;
            continue;
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(rel: &str, src: &str) -> Vec<Diagnostic> {
        let f = LexedFile::lex(rel, &format!("rust/{}", rel), src);
        let mut d = Vec::new();
        check(&f, &mut d);
        d
    }

    #[test]
    fn flags_each_panicking_construct() {
        let src = concat!(
            "fn f() {\n",
            "    a.unwrap();\n",
            "    b.expect(\"m\");\n",
            "    panic!(\"x\");\n",
            "    unreachable!();\n",
            "    todo!();\n",
            "    unimplemented!();\n",
            "    let x = g()[0];\n",
            "}\n",
        );
        let d = run("src/serving/mod.rs", src);
        assert_eq!(d.len(), 7, "{:?}", d);
        let lines: Vec<usize> = d.iter().map(|d| d.line).collect();
        assert_eq!(lines, vec![2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn ignores_lookalike_idents_and_variants() {
        let src = concat!(
            "fn f() {\n",
            "    a.unwrap_or_else(|| 0);\n",
            "    a.unwrap_or_default();\n",
            "    b.expect_err(\"m\");\n",
            "    kernel_panic_point();\n",
            "    no_panic_here();\n",
            "    let v = String::from_utf8_lossy(b);\n",
            "    let b0 = b[0];\n", // plain local indexing: reader idiom
            "}\n",
        );
        let d = run("src/serving/mod.rs", src);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn off_surface_files_are_not_checked() {
        let d = run("src/testutil/mod.rs", "fn f() { a.unwrap(); }\n");
        assert!(d.is_empty());
    }

    #[test]
    fn test_code_and_strings_are_exempt() {
        let src = concat!(
            "fn f() { let m = \"do not .unwrap() this\"; }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() { f().unwrap(); panic!(\"fine in tests\"); }\n",
            "}\n",
        );
        let d = run("src/serving/mod.rs", src);
        assert!(d.is_empty(), "{:?}", d);
    }
}
