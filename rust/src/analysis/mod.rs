//! Self-hosted invariant checker (`tfmicro lint`).
//!
//! A dependency-free static-analysis subsystem that makes the crate's
//! project-level guarantees machine-checked. It replaces the sed/grep
//! `no_panic_gate` in `ci.sh`, which stripped only line comments and
//! everything after the *first* `#[cfg(test)]` — missing block
//! comments, raw strings, multiple test modules, and silently
//! un-checking real code below the first test module. The checks run in
//! three places: the `tfmicro lint` CLI subcommand, `ci.sh`, and the
//! self-hosted gate `rust/tests/lint_gate.rs`, which lints the crate's
//! own sources under plain `cargo test` so tier-1 enforces the
//! invariants with zero extra tooling.
//!
//! # Invariant catalog
//!
//! **`no_panic`** ([`no_panic`]) — the paper's §4.4.1 contract: the
//! framework must never crash the host application. Errors surface as
//! typed `Error` values, never as panics. On the crash-sensitive
//! surface (serving, registry hot-swap, flatbuffer reading, prepared
//! execution, kernel invoke paths) the check forbids `.unwrap()`,
//! `.expect(`, `panic!`, `unreachable!`, `todo!`, `unimplemented!`, and
//! the `)[<const>]` slice-indexing-a-call-result pattern (an implicit
//! bounds panic on data the caller did not validate).
//!
//! **`unsafe_confinement`** — `unsafe` is a property of *modules*, not
//! call sites: it is permitted only in the allowlisted SIMD arch
//! modules (`opt_ops/gemm/*`, `opt_ops/depthwise/*`) and the documented
//! buffer accessors (`ops/mod.rs`, `interpreter/{mod,prepared,shared}`),
//! and every `unsafe` block / fn / impl must be immediately preceded by
//! a safety justification — a `// SAFETY:` comment or, for `unsafe fn`s
//! whose obligation belongs to the caller, a `/// # Safety` doc
//! section. Everywhere else the crate is `unsafe`-free by construction.
//!
//! **`alloc_discipline`** — the warm invoke path is allocation-free
//! (PR 5 pinned this dynamically with a counting allocator; this is the
//! static cousin). Functions annotated `// lint:alloc_free` must not
//! contain `Vec::new`, `vec![`, `.to_vec`, `Box::new`, or
//! `String::from`. A dangling annotation (no `fn` follows) is itself an
//! error, so the marker cannot rot.
//!
//! **`fault_points`** — the deterministic fault-injection points in
//! `faults.rs` stay consistent with their tests: every declared point
//! name must be exercised by `rust/tests/serving_faults.rs` (adding a
//! point without a test fails `cargo test`), and every call site naming
//! a point must name a *declared* one (catches typos that would make an
//! injection site silently dead).
//!
//! **`lock_order`** — the registry's documented lock order (`live`
//! before `history`, everywhere) is checked statically: nested
//! `lock()`/`read()`/`write()` acquisitions per function in `serving/`
//! are extracted and compared against the declared partial order,
//! failing on inversions, re-entry of the same lock, and nesting that
//! involves an undeclared lock (which the order cannot vouch for).
//!
//! # Escape hatch
//!
//! A finding can be suppressed inline with
//! `// lint:allow(<check>): <reason>` on the offending line or the line
//! above. The reason is mandatory; a malformed directive (unknown check
//! name or missing reason) is itself an error, and an unused directive
//! is a warning — allows cannot accumulate silently. Policy: the crate
//! lands with zero allows, or each one carries a written justification
//! that a reviewer can audit.

pub mod alloc_discipline;
pub mod fault_points;
pub mod lexer;
pub mod lock_order;
pub mod no_panic;
pub mod unsafe_confinement;

use lexer::LexedFile;
use std::fs;
use std::path::{Path, PathBuf};

/// Check identifiers, as accepted by `lint:allow(...)`.
pub const CHECKS: &[&str] = &[
    "no_panic",
    "unsafe_confinement",
    "alloc_discipline",
    "fault_points",
    "lock_order",
];

/// How bad a finding is. `--deny-warnings` promotes warnings to
/// failures at the CLI level; the self-hosted gate always denies both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Display path (root-prefixed, e.g. `rust/src/serving/mod.rs`).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Which check fired (one of [`CHECKS`]).
    pub check: &'static str,
    pub message: String,
    pub severity: Severity,
}

impl Diagnostic {
    pub fn render(&self) -> String {
        format!(
            "{}:{}: {} [{}] {}",
            self.file,
            self.line,
            self.severity.as_str(),
            self.check,
            self.message
        )
    }

    /// One-line JSON object (hand-rolled; the crate is dependency-free).
    pub fn render_json(&self) -> String {
        format!(
            "{{\"file\":\"{}\",\"line\":{},\"check\":\"{}\",\"severity\":\"{}\",\"message\":\"{}\"}}",
            json_escape(&self.file),
            self.line,
            self.check,
            self.severity.as_str(),
            json_escape(&self.message)
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Recursively collect `.rs` files under `root/rust/src` and
/// `root/rust/tests`, lexed. `rel_path` is relative to `root/rust`
/// (`src/serving/mod.rs`, `tests/serving_faults.rs`); `display_path`
/// includes the root's last component when it names the repo, else the
/// rel path prefixed with `rust/`.
pub fn collect_sources(root: &Path) -> Result<Vec<LexedFile>, String> {
    let rust_dir = root.join("rust");
    let mut paths: Vec<PathBuf> = Vec::new();
    for sub in ["src", "tests"] {
        let dir = rust_dir.join(sub);
        if dir.is_dir() {
            walk(&dir, &mut paths)?;
        }
    }
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let rel = p
            .strip_prefix(&rust_dir)
            .map_err(|e| format!("path {:?} outside rust dir: {}", p, e))?;
        let rel_path = path_to_slash(rel);
        let display_path = format!("rust/{}", rel_path);
        let source =
            fs::read_to_string(p).map_err(|e| format!("read {}: {}", p.display(), e))?;
        files.push(LexedFile::lex(&rel_path, &display_path, &source));
    }
    Ok(files)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("read_dir {}: {}", dir.display(), e))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {}", dir.display(), e))?;
        let p = entry.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

fn path_to_slash(p: &Path) -> String {
    let mut out = String::new();
    for comp in p.components() {
        if !out.is_empty() {
            out.push('/');
        }
        out.push_str(&comp.as_os_str().to_string_lossy());
    }
    out
}

/// Run every check over the corpus, then apply `lint:allow` directives.
/// Returned diagnostics are sorted by (file, line, check).
pub fn run_checks(files: &[LexedFile]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for f in files {
        no_panic::check(f, &mut diags);
        unsafe_confinement::check(f, &mut diags);
        alloc_discipline::check(f, &mut diags);
    }
    fault_points::check(files, &mut diags);
    lock_order::check(files, &mut diags);
    let mut diags = apply_allows(files, diags);
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.check).cmp(&(b.file.as_str(), b.line, b.check))
    });
    diags
}

/// Lint a repo rooted at `root` (the directory containing `rust/`).
pub fn lint_root(root: &Path) -> Result<Vec<Diagnostic>, String> {
    let files = collect_sources(root)?;
    Ok(run_checks(&files))
}

/// Directive form of a comment: a *plain* `//` comment whose content
/// starts with `lint:`. Doc comments (`///`, `//!`) and block comments
/// carry prose *about* directives, never directives themselves — the
/// invariant catalog above could otherwise lint itself.
pub(crate) fn directive(text: &str) -> Option<&str> {
    let rest = text.strip_prefix("//")?;
    if rest.starts_with('/') || rest.starts_with('!') {
        return None;
    }
    let rest = rest.trim_start();
    if rest.starts_with("lint:") {
        Some(rest)
    } else {
        None
    }
}

struct Allow {
    line: usize,
    check: String,
    used: bool,
}

/// Parse `lint:allow(<check>): <reason>` directives and filter the
/// findings they cover (same line or the line directly below the
/// directive). Malformed directives become errors; unused ones become
/// warnings.
fn apply_allows(files: &[LexedFile], diags: Vec<Diagnostic>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut allows: Vec<(String, Vec<Allow>)> = Vec::new();
    for f in files {
        let mut file_allows = Vec::new();
        for (line, text) in &f.comments {
            let Some(d) = directive(text) else { continue };
            if d.starts_with("lint:alloc_free") {
                continue; // an assertion, owned by alloc_discipline
            }
            let parsed = (|| {
                let rest = d.strip_prefix("lint:allow")?;
                let rest = rest.strip_prefix('(')?;
                let close = rest.find(')')?;
                let check = rest[..close].trim().to_string();
                let reason = rest[close + 1..].trim_start().strip_prefix(':')?.trim();
                if !CHECKS.contains(&check.as_str()) || reason.is_empty() {
                    return None;
                }
                Some(check)
            })();
            match parsed {
                Some(check) => file_allows.push(Allow {
                    line: *line,
                    check,
                    used: false,
                }),
                None => out.push(Diagnostic {
                    file: f.display_path.clone(),
                    line: *line,
                    check: "no_panic",
                    message: format!(
                        "malformed lint:allow directive (want `lint:allow(<check>): <reason>` \
                         with a known check and a non-empty reason): `{}`",
                        text.trim()
                    ),
                    severity: Severity::Error,
                }),
            }
        }
        allows.push((f.display_path.clone(), file_allows));
    }
    for d in diags {
        let suppressed = allows
            .iter_mut()
            .find(|(file, _)| *file == d.file)
            .and_then(|(_, list)| {
                list.iter_mut().find(|a| {
                    a.check == d.check && (a.line == d.line || a.line + 1 == d.line)
                })
            });
        match suppressed {
            Some(a) => a.used = true,
            None => out.push(d),
        }
    }
    for (file, list) in allows {
        for a in list {
            if !a.used {
                out.push(Diagnostic {
                    file: file.clone(),
                    line: a.line,
                    check: "no_panic",
                    message: format!(
                        "unused lint:allow({}) directive — remove it",
                        a.check
                    ),
                    severity: Severity::Warning,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_file(rel: &str, src: &str) -> Vec<LexedFile> {
        vec![LexedFile::lex(rel, &format!("rust/{}", rel), src)]
    }

    #[test]
    fn allow_suppresses_finding_on_next_line() {
        let files = one_file(
            "src/serving/mod.rs",
            "fn f() {\n    // lint:allow(no_panic): test of the escape hatch\n    x.unwrap();\n}\n",
        );
        let diags = run_checks(&files);
        assert!(
            diags.iter().all(|d| !d.message.contains(".unwrap()")),
            "allowed finding must be suppressed: {:?}",
            diags
        );
        assert!(
            !diags.iter().any(|d| d.message.contains("unused lint:allow")),
            "directive was used: {:?}",
            diags
        );
    }

    #[test]
    fn malformed_allow_is_an_error() {
        let files = one_file(
            "src/serving/mod.rs",
            "// lint:allow(no_panic)\nfn f() {}\n",
        );
        let diags = run_checks(&files);
        assert!(diags
            .iter()
            .any(|d| d.severity == Severity::Error && d.message.contains("malformed")));
    }

    #[test]
    fn unknown_check_in_allow_is_an_error() {
        let files = one_file(
            "src/serving/mod.rs",
            "// lint:allow(no_such_check): because\nfn f() {}\n",
        );
        let diags = run_checks(&files);
        assert!(diags.iter().any(|d| d.message.contains("malformed")));
    }

    #[test]
    fn unused_allow_is_a_warning() {
        let files = one_file(
            "src/serving/mod.rs",
            "// lint:allow(no_panic): nothing here actually panics\nfn f() {}\n",
        );
        let diags = run_checks(&files);
        assert!(diags
            .iter()
            .any(|d| d.severity == Severity::Warning
                && d.message.contains("unused lint:allow")));
    }

    #[test]
    fn json_escaping() {
        let d = Diagnostic {
            file: "a\"b".into(),
            line: 3,
            check: "no_panic",
            message: "x\\y\nz".into(),
            severity: Severity::Error,
        };
        assert_eq!(
            d.render_json(),
            "{\"file\":\"a\\\"b\",\"line\":3,\"check\":\"no_panic\",\"severity\":\"error\",\"message\":\"x\\\\y\\nz\"}"
        );
    }
}
