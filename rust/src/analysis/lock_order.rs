//! `lock_order` — static cousin of a race detector for the registry's
//! publish/promote/rollback paths. The registry documents a total lock
//! order (`live` before `history`, everywhere); this check extracts
//! nested `lock()`/`read()`/`write()` acquisitions in `serving/` and
//! fails on:
//!
//! * **inversions** — acquiring an earlier-ordered lock while holding a
//!   later-ordered one (the deadlock shape),
//! * **re-entry** — acquiring a lock already held (self-deadlock with
//!   `Mutex`, writer starvation with `RwLock`),
//! * **undeclared nesting** — any nesting involving a lock not in the
//!   declared order (the order cannot vouch for it; extend the order or
//!   restructure so the guards do not overlap).
//!
//! Guard lifetimes are tracked structurally on the scrubbed text:
//! a `let`-bound guard lives to the end of its enclosing block or an
//! explicit `drop(binding)`; a temporary guard (no `let`) dies at the
//! end of its statement. This is conservative — a guard moved into a
//! struct or returned would be mis-scoped — but the serving code keeps
//! guards local by construction, and the checker exists to keep it so.

use super::lexer::LexedFile;
use super::{Diagnostic, Severity};

/// The declared partial order: a lock may only be acquired while
/// holding locks that appear *earlier* in this list.
pub const ORDER: &[&str] = &["live", "history"];

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

struct Guard {
    /// Receiver field/variable the lock was acquired through.
    name: String,
    /// `let` binding holding the guard, if any.
    binding: Option<String>,
    /// Block depth at acquisition.
    depth: usize,
    line: usize,
}

pub fn check(files: &[LexedFile], diags: &mut Vec<Diagnostic>) {
    for f in files {
        if !f.rel_path.starts_with("src/serving/") {
            continue;
        }
        check_file(f, diags);
    }
}

fn check_file(f: &LexedFile, diags: &mut Vec<Diagnostic>) {
    let text = f.scrubbed_nontest();
    let ch: Vec<char> = text.chars().collect();
    let n = ch.len();
    let mut line = 1usize;
    let mut depth = 0usize;
    let mut stmt_start = 0usize;
    let mut guards: Vec<Guard> = Vec::new();
    let mut i = 0usize;
    while i < n {
        match ch[i] {
            '\n' => line += 1,
            '{' => {
                depth += 1;
                stmt_start = i + 1;
            }
            '}' => {
                depth = depth.saturating_sub(1);
                guards.retain(|g| g.depth <= depth);
                stmt_start = i + 1;
            }
            ';' => {
                guards.retain(|g| g.binding.is_some() || g.depth < depth);
                stmt_start = i + 1;
            }
            '.' => {
                if let Some((method, after)) = lock_method_at(&ch, i) {
                    let name = receiver_before(&ch, i);
                    let binding = let_binding(&ch, stmt_start, i);
                    report_nesting(f, &guards, &name, line, diags);
                    guards.push(Guard {
                        name,
                        binding,
                        depth,
                        line,
                    });
                    let _ = method;
                    i = after;
                    continue;
                }
            }
            'd' if at_ident(&ch, i, "drop") => {
                // drop(binding) releases the named guard early.
                let mut j = i + 4;
                while j < n && ch[j].is_whitespace() {
                    j += 1;
                }
                if j < n && ch[j] == '(' {
                    let mut k = j + 1;
                    while k < n && ch[k].is_whitespace() {
                        k += 1;
                    }
                    let s = k;
                    while k < n && is_ident(ch[k]) {
                        k += 1;
                    }
                    let ident: String = ch[s..k].iter().collect();
                    if !ident.is_empty() {
                        if let Some(pos) = guards.iter().rposition(|g| {
                            g.binding.as_deref() == Some(ident.as_str())
                                || g.name == ident
                        }) {
                            guards.remove(pos);
                        }
                    }
                }
                i += 4;
                continue;
            }
            _ => {}
        }
        i += 1;
    }
}

/// `.lock()` / `.read()` / `.write()` with an empty argument list,
/// starting at the `.` at `i`. Returns the method and the index just
/// past the closing paren.
fn lock_method_at(ch: &[char], i: usize) -> Option<(&'static str, usize)> {
    for m in ["lock", "read", "write"] {
        let p: Vec<char> = m.chars().collect();
        let end = i + 1 + p.len();
        if end <= ch.len()
            && ch[i + 1..end] == p[..]
            && (end == ch.len() || !is_ident(ch[end]))
        {
            let mut j = end;
            while j < ch.len() && ch[j].is_whitespace() {
                j += 1;
            }
            if j < ch.len() && ch[j] == '(' {
                let mut k = j + 1;
                while k < ch.len() && ch[k].is_whitespace() {
                    k += 1;
                }
                if k < ch.len() && ch[k] == ')' {
                    return Some((m, k + 1));
                }
            }
        }
    }
    None
}

/// The ident directly before the `.` at `i` (skipping whitespace, so
/// multi-line builder chains resolve to the field name).
fn receiver_before(ch: &[char], i: usize) -> String {
    let mut j = i;
    while j > 0 && ch[j - 1].is_whitespace() {
        j -= 1;
    }
    let e = j;
    while j > 0 && is_ident(ch[j - 1]) {
        j -= 1;
    }
    let name: String = ch[j..e].iter().collect();
    if name.is_empty() {
        "<expr>".to_string()
    } else {
        name
    }
}

fn at_ident(ch: &[char], i: usize, word: &str) -> bool {
    let p: Vec<char> = word.chars().collect();
    let end = i + p.len();
    end <= ch.len()
        && ch[i..end] == p[..]
        && (i == 0 || !is_ident(ch[i - 1]))
        && (end == ch.len() || !is_ident(ch[end]))
}

/// If the statement beginning at `stmt_start` opens with `let`, the
/// binding name (skipping `mut` and pattern-less forms only).
fn let_binding(ch: &[char], stmt_start: usize, upto: usize) -> Option<String> {
    let mut j = stmt_start.min(upto);
    while j < upto && ch[j].is_whitespace() {
        j += 1;
    }
    if !at_ident(ch, j, "let") {
        return None;
    }
    j += 3;
    while j < upto && ch[j].is_whitespace() {
        j += 1;
    }
    if at_ident(ch, j, "mut") {
        j += 3;
        while j < upto && ch[j].is_whitespace() {
            j += 1;
        }
    }
    let s = j;
    while j < upto && is_ident(ch[j]) {
        j += 1;
    }
    let name: String = ch[s..j].iter().collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

fn report_nesting(
    f: &LexedFile,
    guards: &[Guard],
    acquiring: &str,
    line: usize,
    diags: &mut Vec<Diagnostic>,
) {
    let idx = |name: &str| ORDER.iter().position(|o| *o == name);
    for g in guards {
        let message = if g.name == acquiring {
            format!(
                "re-entrant acquisition of `{}` (already held since line {})",
                acquiring, g.line
            )
        } else {
            match (idx(&g.name), idx(acquiring)) {
                (Some(h), Some(a)) if h > a => format!(
                    "lock-order inversion: acquiring `{}` while holding `{}` \
                     (line {}); declared order is {}",
                    acquiring,
                    g.name,
                    g.line,
                    ORDER.join(" before ")
                ),
                (Some(_), Some(_)) => continue,
                _ => format!(
                    "nested acquisition of `{}` while holding `{}` (line {}) \
                     involves a lock outside the declared order ({}); extend \
                     the order or restructure so the guards do not overlap",
                    acquiring,
                    g.name,
                    g.line,
                    ORDER.join(" before ")
                ),
            }
        };
        diags.push(Diagnostic {
            file: f.display_path.clone(),
            line,
            check: "lock_order",
            message,
            severity: Severity::Error,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> Vec<Diagnostic> {
        let files = vec![LexedFile::lex(
            "src/serving/registry.rs",
            "rust/src/serving/registry.rs",
            src,
        )];
        let mut d = Vec::new();
        check(&files, &mut d);
        d
    }

    #[test]
    fn correct_order_passes() {
        let src = concat!(
            "fn promote(&self) {\n",
            "    let mut live = self.live.write().unwrap_or_else(|p| p.into_inner());\n",
            "    let mut history = self.history.lock().unwrap_or_else(|p| p.into_inner());\n",
            "    history.push(live.clone());\n",
            "}\n",
        );
        assert!(run(src).is_empty(), "{:?}", run(src));
    }

    #[test]
    fn inversion_is_flagged() {
        let src = concat!(
            "fn bad(&self) {\n",
            "    let mut history = self.history.lock().unwrap_or_else(|p| p.into_inner());\n",
            "    let mut live = self.live.write().unwrap_or_else(|p| p.into_inner());\n",
            "    let _ = (&mut history, &mut live);\n",
            "}\n",
        );
        let d = run(src);
        assert_eq!(d.len(), 1, "{:?}", d);
        assert!(d[0].message.contains("inversion"));
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn reentry_is_flagged() {
        let src = concat!(
            "fn bad(&self) {\n",
            "    let a = self.live.read().unwrap();\n",
            "    let b = self.live.read().unwrap();\n",
            "    let _ = (a, b);\n",
            "}\n",
        );
        let d = run(src);
        assert!(
            d.iter().any(|d| d.message.contains("re-entrant")),
            "{:?}",
            d
        );
    }

    #[test]
    fn undeclared_nesting_is_flagged() {
        let src = concat!(
            "fn bad(&self) {\n",
            "    let rx = self.req_rx.lock().unwrap();\n",
            "    let live = self.live.read().unwrap();\n",
            "    let _ = (rx, live);\n",
            "}\n",
        );
        let d = run(src);
        assert_eq!(d.len(), 1, "{:?}", d);
        assert!(d[0].message.contains("outside the declared order"));
    }

    #[test]
    fn block_scope_and_drop_release_guards() {
        let src = concat!(
            "fn ok(&self) {\n",
            "    let req = {\n",
            "        let rx = self.req_rx.lock().unwrap_or_else(|p| p.into_inner());\n",
            "        rx.recv()\n",
            "    };\n",
            "    let mut slot = self.first_init_error.lock().unwrap_or_else(|p| p.into_inner());\n",
            "    *slot = None;\n",
            "    drop(slot);\n",
            "    let live = self.live.read().unwrap_or_else(|p| p.into_inner());\n",
            "    let _ = (req, live);\n",
            "}\n",
        );
        let d = run(src);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn temporary_guard_dies_at_statement_end() {
        let src = concat!(
            "fn ok(&self) -> usize {\n",
            "    self.live.read().unwrap_or_else(|p| p.into_inner()).iter().count();\n",
            "    let h = self.history.lock().unwrap();\n",
            "    h.len()\n",
            "}\n",
        );
        let d = run(src);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn multiline_chain_resolves_receiver() {
        let src = concat!(
            "fn ok(&self) {\n",
            "    let slot = shared\n",
            "        .first_init_error\n",
            "        .lock()\n",
            "        .unwrap_or_else(|p| p.into_inner());\n",
            "    drop(slot);\n",
            "}\n",
        );
        assert!(run(src).is_empty());
    }

    #[test]
    fn non_serving_files_are_skipped() {
        let files = vec![LexedFile::lex(
            "src/runtime/mod.rs",
            "rust/src/runtime/mod.rs",
            "fn f(&self) { let a = self.history.lock().unwrap(); let b = self.live.read().unwrap(); let _ = (a, b); }\n",
        )];
        let mut d = Vec::new();
        check(&files, &mut d);
        assert!(d.is_empty());
    }
}
