//! `fault_points` — keeps the deterministic fault-injection machinery
//! (`src/faults.rs`) consistent with its tests. Two rules, both over
//! the whole corpus:
//!
//! 1. **Coverage** — every point name declared in `faults.rs`
//!    (`pub const NAME: &str = "value";`) must be exercised by
//!    `rust/tests/serving_faults.rs`: referenced there by const ident,
//!    by its point function's name (the `*_point` fn whose body calls
//!    `should_fire(super::NAME, ..)` — mapped from the body, since
//!    e.g. `arena_exhaustion_point` does not name-mangle to
//!    `ARENA_EXHAUSTED`), or by the raw string value. Adding a sixth
//!    point without a test fails `cargo test` via the self-hosted gate.
//! 2. **Declaration** — every call site that passes a *string literal*
//!    as the point argument of `fail_at(..)`, `.seeded(..)`,
//!    `should_fire(..)`, or `injected(..)` must name a declared value;
//!    a typo would otherwise make the injection site silently dead.
//!    (Call sites passing the const ident are checked by the compiler.)

use super::lexer::LexedFile;
use super::{Diagnostic, Severity};

const FAULTS_FILE: &str = "src/faults.rs";
const TESTS_FILE: &str = "tests/serving_faults.rs";

/// The point argument is the first argument for these callables.
const POINT_CALLS: &[&str] = &["fail_at", "seeded", "should_fire", "injected"];

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn has_token(text: &str, word: &str) -> bool {
    let ch: Vec<char> = text.chars().collect();
    let p: Vec<char> = word.chars().collect();
    if ch.len() < p.len() {
        return false;
    }
    for s in 0..=ch.len() - p.len() {
        if ch[s..s + p.len()] == p[..]
            && (s == 0 || !is_ident(ch[s - 1]))
            && (s + p.len() == ch.len() || !is_ident(ch[s + p.len()]))
        {
            return true;
        }
    }
    false
}

struct Point {
    ident: String,
    value: String,
    line: usize,
    /// `*_point` fns whose bodies reference this const.
    fns: Vec<String>,
}

pub fn check(files: &[LexedFile], diags: &mut Vec<Diagnostic>) {
    let Some(faults) = files.iter().find(|f| f.rel_path == FAULTS_FILE) else {
        return;
    };
    let mut points = declared_points(faults);
    map_point_fns(faults, &mut points);
    let declared: Vec<&str> = points.iter().map(|p| p.value.as_str()).collect();

    // Rule 1: every declared point is exercised by the fault tests.
    match files.iter().find(|f| f.rel_path == TESTS_FILE) {
        Some(tests) => {
            for p in &points {
                let by_ident = has_token(&tests.scrubbed, &p.ident);
                let by_fn = p.fns.iter().any(|f| has_token(&tests.scrubbed, f));
                let by_value = tests.strings.iter().any(|s| s.value == p.value);
                if !(by_ident || by_fn || by_value) {
                    diags.push(Diagnostic {
                        file: faults.display_path.clone(),
                        line: p.line,
                        check: "fault_points",
                        message: format!(
                            "fault point {} (\"{}\") is not exercised by {}; \
                             add a test before declaring the point",
                            p.ident, p.value, TESTS_FILE
                        ),
                        severity: Severity::Error,
                    });
                }
            }
        }
        None => {
            for p in &points {
                diags.push(Diagnostic {
                    file: faults.display_path.clone(),
                    line: p.line,
                    check: "fault_points",
                    message: format!(
                        "fault point {} declared but {} is missing",
                        p.ident, TESTS_FILE
                    ),
                    severity: Severity::Error,
                });
            }
        }
    }

    // Rule 2: string-literal point arguments must be declared values.
    for f in files {
        check_call_sites(f, &declared, diags);
    }
}

/// Parse `const IDENT: &str = "value";` declarations (outside test
/// code). String values are blanked in the scrubbed text, so each is
/// recovered from the literal side table by line.
fn declared_points(faults: &LexedFile) -> Vec<Point> {
    let mut out = Vec::new();
    for s in &faults.strings {
        if faults.is_test_line(s.line) || s.line > faults.code_lines.len() {
            continue;
        }
        let code = &faults.code_lines[s.line - 1];
        if !has_token(code, "const") || !code.contains("&str") {
            continue;
        }
        // Ident after the `const` token.
        let ch: Vec<char> = code.chars().collect();
        let Some(at) = code.find("const") else { continue };
        let mut j = at + 5;
        while j < ch.len() && ch[j].is_whitespace() {
            j += 1;
        }
        let b = j;
        while j < ch.len() && is_ident(ch[j]) {
            j += 1;
        }
        let ident: String = ch[b..j].iter().collect();
        if !ident.is_empty() {
            out.push(Point {
                ident,
                value: s.value.clone(),
                line: s.line,
                fns: Vec::new(),
            });
        }
    }
    out
}

/// Attribute each `should_fire(super::IDENT, ..)` reference inside
/// `faults.rs` to its enclosing fn, giving the const → point-fn map.
fn map_point_fns(faults: &LexedFile, points: &mut [Point]) {
    let ch: Vec<char> = faults.scrubbed.chars().collect();
    let n = ch.len();
    // Collect (fn name, body start, body end).
    let mut fns: Vec<(String, usize, usize)> = Vec::new();
    let mut i = 0usize;
    while i < n {
        if ch[i] == 'f' && i + 1 < n && ch[i + 1] == 'n' {
            let bounded = (i == 0 || !is_ident(ch[i - 1]))
                && (i + 2 == n || !is_ident(ch[i + 2]));
            if bounded {
                let mut j = i + 2;
                while j < n && ch[j].is_whitespace() {
                    j += 1;
                }
                let b = j;
                while j < n && is_ident(ch[j]) {
                    j += 1;
                }
                let name: String = ch[b..j].iter().collect();
                if !name.is_empty() {
                    let mut pd = 0isize;
                    let mut k = j;
                    while k < n {
                        match ch[k] {
                            '(' | '[' => pd += 1,
                            ')' | ']' => pd -= 1,
                            ';' if pd == 0 => break,
                            '{' if pd == 0 => {
                                let start = k;
                                let mut bd = 1usize;
                                k += 1;
                                while k < n && bd > 0 {
                                    match ch[k] {
                                        '{' => bd += 1,
                                        '}' => bd -= 1,
                                        _ => {}
                                    }
                                    k += 1;
                                }
                                fns.push((name.clone(), start, k));
                                break;
                            }
                            _ => {}
                        }
                        k += 1;
                    }
                    i = j;
                    continue;
                }
            }
        }
        i += 1;
    }
    // Find `should_fire` references and the const ident that follows.
    let sf: Vec<char> = "should_fire".chars().collect();
    let mut i = 0usize;
    while i + sf.len() <= n {
        if ch[i..i + sf.len()] == sf[..]
            && (i == 0 || !is_ident(ch[i - 1]))
            && !is_ident(*ch.get(i + sf.len()).unwrap_or(&' '))
        {
            let mut j = i + sf.len();
            while j < n && ch[j].is_whitespace() {
                j += 1;
            }
            if j < n && ch[j] == '(' {
                j += 1;
                // Optional path prefix (`super::`, `faults::`, ...).
                loop {
                    while j < n && ch[j].is_whitespace() {
                        j += 1;
                    }
                    let b = j;
                    while j < n && is_ident(ch[j]) {
                        j += 1;
                    }
                    if j + 1 < n && ch[j] == ':' && ch[j + 1] == ':' {
                        j += 2;
                        continue;
                    }
                    let ident: String = ch[b..j].iter().collect();
                    if let Some(p) = points.iter_mut().find(|p| p.ident == ident) {
                        // Innermost enclosing fn = the one with the
                        // tightest body span around this reference.
                        if let Some((name, _, _)) = fns
                            .iter()
                            .filter(|(_, s, e)| *s < i && i < *e)
                            .min_by_key(|(_, s, e)| e - s)
                        {
                            if !p.fns.contains(name) {
                                p.fns.push(name.clone());
                            }
                        }
                    }
                    break;
                }
            }
        }
        i += 1;
    }
}

/// Flag string-literal point arguments that name no declared value.
fn check_call_sites(f: &LexedFile, declared: &[&str], diags: &mut Vec<Diagnostic>) {
    let ch: Vec<char> = f.scrubbed.chars().collect();
    let n = ch.len();
    for call in POINT_CALLS {
        let p: Vec<char> = call.chars().collect();
        if n < p.len() {
            continue;
        }
        let mut i = 0usize;
        while i + p.len() <= n {
            if ch[i..i + p.len()] != p[..]
                || (i > 0 && is_ident(ch[i - 1]))
                || is_ident(*ch.get(i + p.len()).unwrap_or(&' '))
            {
                i += 1;
                continue;
            }
            let mut j = i + p.len();
            while j < n && ch[j].is_whitespace() {
                j += 1;
            }
            if j >= n || ch[j] != '(' {
                i += 1;
                continue;
            }
            // First argument: up to the first `,` at depth 1 or the
            // matching `)`.
            let arg_start = j + 1;
            let mut depth = 1isize;
            let mut k = arg_start;
            while k < n && depth > 0 {
                match ch[k] {
                    '(' | '[' | '{' => depth += 1,
                    ')' | ']' | '}' => depth -= 1,
                    ',' if depth == 1 => break,
                    _ => {}
                }
                k += 1;
            }
            let arg_end = k;
            for s in &f.strings {
                if s.pos >= arg_start && s.pos < arg_end && !declared.contains(&s.value.as_str())
                {
                    diags.push(Diagnostic {
                        file: f.display_path.clone(),
                        line: s.line,
                        check: "fault_points",
                        message: format!(
                            "{}(\"{}\", ..) names no declared fault point \
                             (declared: {})",
                            call,
                            s.value,
                            declared.join(", ")
                        ),
                        severity: Severity::Error,
                    });
                }
            }
            i = arg_end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FAULTS_SRC: &str = concat!(
        "pub const KERNEL_PANIC: &str = \"kernel_panic\";\n",
        "pub const ARENA_EXHAUSTED: &str = \"arena_exhausted\";\n",
        "mod active {\n",
        "    pub fn kernel_panic_point(op: &str) {\n",
        "        if should_fire(super::KERNEL_PANIC, Some(op)) {}\n",
        "    }\n",
        "    pub fn arena_exhaustion_point() {\n",
        "        if should_fire(super::ARENA_EXHAUSTED, None) {}\n",
        "    }\n",
        "}\n",
    );

    fn lex(rel: &str, src: &str) -> LexedFile {
        LexedFile::lex(rel, &format!("rust/{}", rel), src)
    }

    fn run(files: Vec<LexedFile>) -> Vec<Diagnostic> {
        let mut d = Vec::new();
        check(&files, &mut d);
        d
    }

    #[test]
    fn covered_points_pass() {
        let tests = lex(
            TESTS_FILE,
            // One point by const ident, the other by mapped fn name.
            "fn t() { f(faults::KERNEL_PANIC); arena_exhaustion_point(); }\n",
        );
        let d = run(vec![lex(FAULTS_FILE, FAULTS_SRC), tests]);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn unexercised_point_is_flagged() {
        let tests = lex(TESTS_FILE, "fn t() { f(faults::KERNEL_PANIC); }\n");
        let d = run(vec![lex(FAULTS_FILE, FAULTS_SRC), tests]);
        assert_eq!(d.len(), 1, "{:?}", d);
        assert!(d[0].message.contains("ARENA_EXHAUSTED"));
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn coverage_by_string_value_counts() {
        let tests = lex(
            TESTS_FILE,
            "fn t() { f(faults::KERNEL_PANIC); g(\"arena_exhausted\"); }\n",
        );
        let d = run(vec![lex(FAULTS_FILE, FAULTS_SRC), tests]);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn typo_in_string_point_argument_is_flagged() {
        let tests = lex(
            TESTS_FILE,
            concat!(
                "fn t() {\n",
                "    plan.fail_at(\"kernel_panik\", None, &[0]);\n",
                "    arena_exhaustion_point(); kernel_panic_point(\"op\");\n",
                "}\n",
            ),
        );
        let d = run(vec![lex(FAULTS_FILE, FAULTS_SRC), tests]);
        assert_eq!(d.len(), 1, "{:?}", d);
        assert!(d[0].message.contains("kernel_panik"));
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn second_argument_strings_are_not_point_names() {
        let tests = lex(
            TESTS_FILE,
            concat!(
                "fn t() {\n",
                "    plan.fail_at(faults::KERNEL_PANIC, Some(\"FULLY_CONNECTED\"), &[4]);\n",
                "    arena_exhaustion_point();\n",
                "}\n",
            ),
        );
        let d = run(vec![lex(FAULTS_FILE, FAULTS_SRC), tests]);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn missing_tests_file_flags_every_point() {
        let d = run(vec![lex(FAULTS_FILE, FAULTS_SRC)]);
        assert_eq!(d.len(), 2, "{:?}", d);
        assert!(d.iter().all(|d| d.message.contains("missing")));
    }

    #[test]
    fn no_faults_file_is_a_no_op() {
        let d = run(vec![lex("src/lib.rs", "fn f() {}\n")]);
        assert!(d.is_empty());
    }
}
