//! `unsafe_confinement` — `unsafe` is a property of modules, not call
//! sites. It is permitted only in the allowlisted SIMD arch modules and
//! the documented buffer accessors, and every `unsafe` occurrence must
//! be immediately preceded by (or share a line with) a safety
//! justification: a `// SAFETY:` comment (the idiom for `unsafe`
//! blocks and impls) or a `/// # Safety` doc section (the idiom for
//! `unsafe fn`s, where the obligation belongs to the caller). The
//! upward scan walks through comment-only and attribute-only lines (a
//! safety paragraph may span several lines, and `#[target_feature]`
//! may sit between it and the fn); a code line or a blank line stops
//! it — "immediately preceded" means no unrelated material in between.

use super::lexer::{LexedFile, LineKind};
use super::{Diagnostic, Severity};

/// Directory prefixes where `unsafe` is allowed (SIMD arch modules).
pub const ALLOWED_PREFIXES: &[&str] =
    &["src/ops/opt_ops/gemm/", "src/ops/opt_ops/depthwise/"];

/// Individual files where `unsafe` is allowed: the documented buffer
/// accessors, the Send/Sync impls of the shared prepared model, and the
/// counting `GlobalAlloc` shim the allocation-accounting test installs.
pub const ALLOWED_FILES: &[&str] = &[
    "src/ops/mod.rs",
    "src/interpreter/mod.rs",
    "src/interpreter/prepared.rs",
    "src/interpreter/shared.rs",
    "tests/invoke_accounting.rs",
];

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn has_unsafe_token(line: &str) -> bool {
    let ch: Vec<char> = line.chars().collect();
    let pat: Vec<char> = "unsafe".chars().collect();
    let n = ch.len();
    if n < pat.len() {
        return false;
    }
    for s in 0..=n - pat.len() {
        if ch[s..s + pat.len()] == pat[..]
            && (s == 0 || !is_ident(ch[s - 1]))
            && (s + pat.len() == n || !is_ident(ch[s + pat.len()]))
        {
            return true;
        }
    }
    false
}

fn allowed(rel: &str) -> bool {
    ALLOWED_FILES.contains(&rel) || ALLOWED_PREFIXES.iter().any(|p| rel.starts_with(p))
}

fn has_safety_marker(text: &str) -> bool {
    text.contains("SAFETY:") || text.contains("# Safety")
}

/// True when `line` carries or is directly preceded by a safety
/// justification (`// SAFETY:` or a `/// # Safety` doc section).
fn safety_adjacent(f: &LexedFile, line: usize) -> bool {
    if has_safety_marker(&f.comment_text(line)) {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        match f.line_kind(l) {
            LineKind::CommentOnly | LineKind::AttrOnly => {
                if has_safety_marker(&f.comment_text(l)) {
                    return true;
                }
            }
            LineKind::Code | LineKind::Blank => return false,
        }
    }
    false
}

pub fn check(f: &LexedFile, diags: &mut Vec<Diagnostic>) {
    let file_allowed = allowed(&f.rel_path);
    for (idx, code) in f.code_lines.iter().enumerate() {
        let line = idx + 1;
        if f.is_test_line(line) || !has_unsafe_token(code) {
            continue;
        }
        if !file_allowed {
            diags.push(Diagnostic {
                file: f.display_path.clone(),
                line,
                check: "unsafe_confinement",
                message: format!(
                    "`unsafe` is confined to the arch modules ({} and the documented \
                     buffer accessors); {} is not allowlisted",
                    ALLOWED_PREFIXES.join(", "),
                    f.rel_path
                ),
                severity: Severity::Error,
            });
        } else if !safety_adjacent(f, line) {
            diags.push(Diagnostic {
                file: f.display_path.clone(),
                line,
                check: "unsafe_confinement",
                message: "`unsafe` must be immediately preceded by a `// SAFETY:` \
                          comment or a `/// # Safety` doc section stating the \
                          obligation discharged"
                    .to_string(),
                severity: Severity::Error,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(rel: &str, src: &str) -> Vec<Diagnostic> {
        let f = LexedFile::lex(rel, &format!("rust/{}", rel), src);
        let mut d = Vec::new();
        check(&f, &mut d);
        d
    }

    #[test]
    fn unsafe_outside_allowlist_is_flagged() {
        let d = run(
            "src/serving/mod.rs",
            "// SAFETY: even with a comment, the module is not allowlisted\nunsafe { x() }\n",
        );
        assert_eq!(d.len(), 1, "{:?}", d);
        assert!(d[0].message.contains("not allowlisted"));
    }

    #[test]
    fn safety_comment_satisfies_the_rule() {
        let src = concat!(
            "// SAFETY: lane count checked by the dispatcher\n",
            "#[target_feature(enable = \"avx2\")]\n",
            "unsafe fn kernel() {}\n",
            "\n",
            "// SAFETY: pointer provenance spans\n",
            "// two comment lines of justification\n",
            "unsafe fn other() {}\n",
        );
        let d = run("src/ops/opt_ops/gemm/avx2.rs", src);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn safety_doc_section_satisfies_the_rule() {
        let src = concat!(
            "/// Dot product over packed weights.\n",
            "///\n",
            "/// # Safety\n",
            "/// Caller guarantees avx2 and the packed-layout bounds.\n",
            "#[target_feature(enable = \"avx2\")]\n",
            "unsafe fn dot(x: &[i8]) {}\n",
        );
        let d = run("src/ops/opt_ops/gemm/avx2.rs", src);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn missing_safety_comment_is_flagged() {
        let d = run("src/ops/opt_ops/gemm/avx2.rs", "unsafe fn kernel() {}\n");
        assert_eq!(d.len(), 1, "{:?}", d);
        assert!(d[0].message.contains("SAFETY:"));
    }

    #[test]
    fn blank_line_breaks_adjacency() {
        let src = "// SAFETY: too far away\n\nunsafe fn kernel() {}\n";
        let d = run("src/ops/opt_ops/gemm/avx2.rs", src);
        assert_eq!(d.len(), 1, "{:?}", d);
    }

    #[test]
    fn same_line_safety_and_ident_lookalikes() {
        let src = concat!(
            "unsafe impl Send for X {} // SAFETY: buffers are owned\n",
            "fn notes() { let unsafe_count = 0; let _ = unsafe_count; }\n",
            "fn words() { let s = \"unsafe in a string\"; let _ = s; }\n",
        );
        let d = run("src/ops/opt_ops/gemm/mod.rs", src);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { unsafe { x() } }\n}\n";
        let d = run("src/serving/mod.rs", src);
        assert!(d.is_empty(), "{:?}", d);
    }
}
