//! `alloc_discipline` — the warm invoke path allocates nothing. PR 5
//! pinned this dynamically with a counting allocator; this check is the
//! static cousin: a function annotated with a `// lint:alloc_free`
//! comment must not contain `Vec::new`, `vec![`, `.to_vec`, `Box::new`,
//! or `String::from`. The annotation is an assertion, not a
//! suppression — a dangling annotation (no `fn` follows) is itself an
//! error so the marker cannot rot when code moves.

use super::lexer::{LexedFile, LineKind};
use super::{Diagnostic, Severity};

/// Allocation tokens forbidden inside `lint:alloc_free` functions.
const FORBIDDEN: &[&str] = &["Vec::new", "vec!", ".to_vec", "Box::new", "String::from"];

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `pat` occurs in `ch[..]` at ident boundaries (only enforced on ends
/// of the pattern that are themselves ident chars, so `.to_vec` needs
/// no boundary before the dot but `String::from` must not match
/// `String::from_utf8_lossy`).
fn find_token(ch: &[char], pat: &str, from: usize) -> Option<usize> {
    let p: Vec<char> = pat.chars().collect();
    let n = ch.len();
    if n < p.len() {
        return None;
    }
    let head_ident = is_ident(p[0]);
    let tail_ident = is_ident(p[p.len() - 1]);
    for s in from..=n - p.len() {
        if ch[s..s + p.len()] == p[..]
            && (!head_ident || s == 0 || !is_ident(ch[s - 1]))
            && (!tail_ident || s + p.len() == n || !is_ident(ch[s + p.len()]))
        {
            return Some(s);
        }
    }
    None
}

pub fn check(f: &LexedFile, diags: &mut Vec<Diagnostic>) {
    let ann_lines: Vec<usize> = f
        .comments
        .iter()
        .filter(|(_, t)| {
            super::directive(t).map(|d| d.starts_with("lint:alloc_free")).unwrap_or(false)
        })
        .map(|(l, _)| *l)
        .collect();
    if ann_lines.is_empty() {
        return;
    }
    let ch: Vec<char> = f.scrubbed.chars().collect();
    let mut line_start = vec![0usize];
    for (k, c) in ch.iter().enumerate() {
        if *c == '\n' {
            line_start.push(k + 1);
        }
    }
    let mut dangling = |line: usize, diags: &mut Vec<Diagnostic>| {
        diags.push(Diagnostic {
            file: f.display_path.clone(),
            line,
            check: "alloc_discipline",
            message: "dangling lint:alloc_free annotation — no fn with a body follows"
                .to_string(),
            severity: Severity::Error,
        });
    };
    for &al in &ann_lines {
        // The annotated fn: the first code line at/below the annotation
        // (comment/attr/blank lines in between are fine) must contain a
        // `fn` token.
        let mut l = al;
        let fn_line = loop {
            if l > f.code_lines.len() {
                break None;
            }
            if f.line_kind(l) == LineKind::Code {
                if find_token(
                    &f.code_lines[l - 1].chars().collect::<Vec<_>>(),
                    "fn",
                    0,
                )
                .is_some()
                {
                    break Some(l);
                }
                break None;
            }
            l += 1;
        };
        let Some(fn_line) = fn_line else {
            dangling(al, diags);
            continue;
        };
        // Body extent: first `{` at paren/bracket depth 0, brace-matched.
        let mut i = line_start[fn_line - 1];
        let n = ch.len();
        let mut pd = 0isize;
        let mut body = None;
        while i < n {
            match ch[i] {
                '(' | '[' => pd += 1,
                ')' | ']' => pd -= 1,
                ';' if pd == 0 => break,
                '{' if pd == 0 => {
                    let start = i;
                    let mut bd = 1usize;
                    i += 1;
                    while i < n && bd > 0 {
                        match ch[i] {
                            '{' => bd += 1,
                            '}' => bd -= 1,
                            _ => {}
                        }
                        i += 1;
                    }
                    body = Some((start, i));
                    break;
                }
                _ => {}
            }
            i += 1;
        }
        let Some((bstart, bend)) = body else {
            dangling(al, diags);
            continue;
        };
        for pat in FORBIDDEN {
            let mut from = bstart;
            while let Some(at) = find_token(&ch[..bend], pat, from) {
                diags.push(Diagnostic {
                    file: f.display_path.clone(),
                    line: f.line_of(at),
                    check: "alloc_discipline",
                    message: format!(
                        "`{}` in a lint:alloc_free function (annotated at line {})",
                        pat, al
                    ),
                    severity: Severity::Error,
                });
                from = at + pat.chars().count();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> Vec<Diagnostic> {
        let f = LexedFile::lex("src/runtime/mod.rs", "rust/src/runtime/mod.rs", src);
        let mut d = Vec::new();
        check(&f, &mut d);
        d
    }

    #[test]
    fn flags_every_forbidden_token() {
        let src = concat!(
            "// lint:alloc_free\n",
            "fn warm() {\n",
            "    let a = Vec::new();\n",
            "    let b = vec![0u8; 4];\n",
            "    let c = s.to_vec();\n",
            "    let d = Box::new(1);\n",
            "    let e = String::from(\"x\");\n",
            "}\n",
        );
        let d = run(src);
        assert_eq!(d.len(), 5, "{:?}", d);
        assert!(d.iter().all(|d| d.check == "alloc_discipline"));
    }

    #[test]
    fn clean_annotated_fn_and_unannotated_neighbors_pass() {
        let src = concat!(
            "// lint:alloc_free — hot path\n",
            "#[inline]\n",
            "fn warm(buf: &mut [u8]) { buf.fill(0); }\n",
            "fn cold() { let v = Vec::new(); drop(v); }\n",
        );
        let d = run(src);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn lookalikes_are_not_flagged() {
        let src = concat!(
            "// lint:alloc_free\n",
            "fn warm(b: &[u8]) {\n",
            "    let s = String::from_utf8_lossy(b);\n",
            "    let msg = \"never Vec::new here\";\n",
            "    let _ = (s, msg);\n",
            "}\n",
        );
        let d = run(src);
        assert!(d.is_empty(), "{:?}", d);
    }

    #[test]
    fn dangling_annotation_is_an_error() {
        let d = run("// lint:alloc_free\nstatic X: u8 = 0;\n");
        assert_eq!(d.len(), 1, "{:?}", d);
        assert!(d[0].message.contains("dangling"));
    }
}
