//! A small, dependency-free Rust lexer for the invariant checker.
//!
//! The old CI gate was a sed/grep pipeline: it stripped `//` comments and
//! everything after the *first* `#[cfg(test)]`, which (a) misses block
//! comments, (b) false-positives on panicking tokens inside string
//! literals, (c) breaks on `//` *inside* a string (the rest of the line
//! vanished, hiding real code), and (d) silently un-checks every line
//! below the first test module — including real code between two test
//! modules. This lexer fixes all four by actually classifying every
//! character of the source:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments
//!   (`/* /* */ */`),
//! * string literals with escapes, byte strings, and raw strings with an
//!   arbitrary hash count (`r#"..."#`, `br##"..."##`) — distinguished
//!   from raw identifiers (`r#fn`),
//! * char and byte-char literals, including `'"'`, `'}'`, and escape
//!   forms (`'\''`, `'\u{7D}'`), distinguished from lifetimes (`'a`,
//!   `'respawn: loop`),
//! * `#[cfg(test)]`-gated items and `mod tests { ... }` blocks, excluded
//!   by brace tracking — *every* such region, not just the first, and
//!   only the region itself (code between two test modules stays
//!   checked). `#[cfg(any(test, ...))]` is **not** excluded: such items
//!   are compiled into debug builds and must hold the invariants.
//!
//! The output is a [`LexedFile`]: a *scrubbed* view of the source where
//! every non-code character is blanked to a space (line structure
//! preserved, so diagnostics carry real line numbers), plus the comment
//! text per line (for `// SAFETY:` / `// lint:` directives) and every
//! string literal with its position (for the fault-point name check).

/// A string literal found in the source (contents are blanked in the
/// scrubbed view; the value lives here).
#[derive(Debug, Clone)]
pub struct StrLit {
    /// Char offset of the opening quote in the scrubbed text.
    pub pos: usize,
    /// 1-based line of the opening quote.
    pub line: usize,
    /// Literal contents between the quotes, escapes left as written.
    pub value: String,
}

/// Classification of a line for comment-adjacency rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LineKind {
    /// Contains at least one code token.
    Code,
    /// Only a comment (no code, no attribute).
    CommentOnly,
    /// Only an attribute (`#[...]` / `#![...]`), possibly with a comment.
    AttrOnly,
    /// Nothing at all.
    Blank,
}

/// A source file after lexing: code-only text plus comment/string side
/// tables and the test-region mask.
pub struct LexedFile {
    /// Path relative to the lint root — what check configs key on.
    pub rel_path: String,
    /// Path as shown in diagnostics (usually prefixed with the root).
    pub display_path: String,
    /// Scrubbed text: comments and literal contents replaced by spaces,
    /// char-for-char (newlines preserved), so offsets map to lines.
    pub scrubbed: String,
    /// Scrubbed text split into lines (no terminators).
    pub code_lines: Vec<String>,
    /// Comment text per line (a block comment contributes one entry per
    /// line it spans). A line can appear more than once.
    pub comments: Vec<(usize, String)>,
    /// Every string literal, in source order.
    pub strings: Vec<StrLit>,
    /// `test_line[line - 1]` is true inside `#[cfg(test)]` items and
    /// `mod tests` blocks.
    pub test_line: Vec<bool>,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

impl LexedFile {
    /// Lex `source`, classifying every char and marking test regions.
    pub fn lex(rel_path: &str, display_path: &str, source: &str) -> LexedFile {
        let ch: Vec<char> = source.chars().collect();
        let n = ch.len();
        let mut scrubbed = String::with_capacity(n);
        let mut comments: Vec<(usize, String)> = Vec::new();
        let mut strings: Vec<StrLit> = Vec::new();
        let mut line = 1usize;
        let mut i = 0usize;

        // Push `count` blanks preserving newlines from ch[i..i+count].
        // Returns the new line number.
        fn blank(scrubbed: &mut String, ch: &[char], from: usize, to: usize, line: &mut usize) {
            for &c in &ch[from..to] {
                if c == '\n' {
                    scrubbed.push('\n');
                    *line += 1;
                } else {
                    scrubbed.push(' ');
                }
            }
        }

        while i < n {
            let c = ch[i];
            let prev_ident = i > 0 && is_ident(ch[i - 1]);
            // --- comments --------------------------------------------
            if c == '/' && i + 1 < n && ch[i + 1] == '/' {
                let start = i;
                while i < n && ch[i] != '\n' {
                    i += 1;
                }
                comments.push((line, ch[start..i].iter().collect()));
                blank(&mut scrubbed, &ch, start, i, &mut line);
                continue;
            }
            if c == '/' && i + 1 < n && ch[i + 1] == '*' {
                let mut depth = 1usize;
                let mut cur = String::from("/*");
                let mut cline = line;
                let start = i;
                i += 2;
                while i < n && depth > 0 {
                    if ch[i] == '/' && i + 1 < n && ch[i + 1] == '*' {
                        depth += 1;
                        cur.push_str("/*");
                        i += 2;
                    } else if ch[i] == '*' && i + 1 < n && ch[i + 1] == '/' {
                        depth -= 1;
                        cur.push_str("*/");
                        i += 2;
                    } else {
                        if ch[i] == '\n' {
                            comments.push((cline, std::mem::take(&mut cur)));
                            cline += 1;
                        } else {
                            cur.push(ch[i]);
                        }
                        i += 1;
                    }
                }
                if !cur.is_empty() {
                    comments.push((cline, cur));
                }
                blank(&mut scrubbed, &ch, start, i, &mut line);
                continue;
            }
            // --- raw strings: r"..", r#".."#, br".."  ----------------
            if (c == 'r' || (c == 'b' && i + 1 < n && ch[i + 1] == 'r')) && !prev_ident {
                let mut j = i + if c == 'b' { 2 } else { 1 };
                let mut hashes = 0usize;
                while j < n && ch[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && ch[j] == '"' {
                    // Raw (byte) string; `r#ident` falls through (no quote).
                    let open_line = line;
                    let pos = scrubbed.chars().count() + (j - i);
                    let content_start = j + 1;
                    let mut k = content_start;
                    'findend: while k < n {
                        if ch[k] == '"' {
                            let mut h = 0usize;
                            while h < hashes && k + 1 + h < n && ch[k + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                break 'findend;
                            }
                        }
                        k += 1;
                    }
                    let end = (k + 1 + hashes).min(n);
                    strings.push(StrLit {
                        pos,
                        line: open_line,
                        value: ch[content_start..k.min(n)].iter().collect(),
                    });
                    blank(&mut scrubbed, &ch, i, end, &mut line);
                    i = end;
                    continue;
                }
            }
            // --- plain / byte strings --------------------------------
            if c == '"' || (c == 'b' && i + 1 < n && ch[i + 1] == '"' && !prev_ident) {
                let quote = if c == 'b' { i + 1 } else { i };
                let pos = scrubbed.chars().count() + (quote - i);
                let open_line = line;
                let mut k = quote + 1;
                while k < n {
                    if ch[k] == '\\' {
                        k += 2;
                        continue;
                    }
                    if ch[k] == '"' {
                        break;
                    }
                    k += 1;
                }
                let end = (k + 1).min(n);
                strings.push(StrLit {
                    pos,
                    line: open_line,
                    value: ch[quote + 1..k.min(n)].iter().collect(),
                });
                blank(&mut scrubbed, &ch, i, end, &mut line);
                i = end;
                continue;
            }
            // --- char / byte-char literals vs lifetimes --------------
            if c == '\'' || (c == 'b' && i + 1 < n && ch[i + 1] == '\'' && !prev_ident) {
                let quote = if c == 'b' { i + 1 } else { i };
                let s = quote + 1;
                let is_char_lit = if s < n && ch[s] == '\\' {
                    true
                } else {
                    // 'X' where the char after X closes the quote. A
                    // lifetime ('a, 'respawn, '_) never has that.
                    s + 1 < n && ch[s] != '\'' && ch[s + 1] == '\''
                };
                if is_char_lit {
                    let mut k = s;
                    while k < n {
                        if ch[k] == '\\' {
                            k += 2;
                            continue;
                        }
                        if ch[k] == '\'' {
                            break;
                        }
                        k += 1;
                    }
                    let end = (k + 1).min(n);
                    blank(&mut scrubbed, &ch, i, end, &mut line);
                    i = end;
                    continue;
                }
                // Lifetime or label: the quote itself is code.
                scrubbed.push(c);
                i += 1;
                continue;
            }
            // --- plain code ------------------------------------------
            if c == '\n' {
                line += 1;
            }
            scrubbed.push(c);
            i += 1;
        }

        let code_lines: Vec<String> = scrubbed.split('\n').map(str::to_string).collect();
        let nlines = code_lines.len();
        let test_line = mark_test_regions(&scrubbed, nlines);
        LexedFile {
            rel_path: rel_path.to_string(),
            display_path: display_path.to_string(),
            scrubbed,
            code_lines,
            comments,
            strings,
            test_line,
        }
    }

    /// True when `line` (1-based) is inside a test-only region.
    pub fn is_test_line(&self, line: usize) -> bool {
        line >= 1 && line <= self.test_line.len() && self.test_line[line - 1]
    }

    /// 1-based line of a char offset into the scrubbed text.
    pub fn line_of(&self, pos: usize) -> usize {
        let mut line = 1usize;
        for (k, c) in self.scrubbed.chars().enumerate() {
            if k >= pos {
                break;
            }
            if c == '\n' {
                line += 1;
            }
        }
        line
    }

    /// The scrubbed text with test-region lines additionally blanked —
    /// the input for whole-file scans that must skip tests.
    pub fn scrubbed_nontest(&self) -> String {
        let mut out = String::with_capacity(self.scrubbed.len());
        for (idx, l) in self.code_lines.iter().enumerate() {
            if idx > 0 {
                out.push('\n');
            }
            if self.test_line[idx] {
                out.extend(std::iter::repeat(' ').take(l.chars().count()));
            } else {
                out.push_str(l);
            }
        }
        out
    }

    /// Classify a line for the comment-adjacency rules.
    pub fn line_kind(&self, line: usize) -> LineKind {
        if line < 1 || line > self.code_lines.len() {
            return LineKind::Blank;
        }
        let code = self.code_lines[line - 1].trim();
        let has_comment = self.comments.iter().any(|(l, _)| *l == line);
        if code.is_empty() {
            if has_comment {
                LineKind::CommentOnly
            } else {
                LineKind::Blank
            }
        } else if code.starts_with("#[") || code.starts_with("#![") {
            LineKind::AttrOnly
        } else {
            LineKind::Code
        }
    }

    /// All comment text on a line, concatenated.
    pub fn comment_text(&self, line: usize) -> String {
        let mut out = String::new();
        for (l, t) in &self.comments {
            if *l == line {
                out.push_str(t);
                out.push(' ');
            }
        }
        out
    }
}

/// Mark every line belonging to a `#[cfg(test)]` item or a
/// `mod tests { ... }` block. Operates on scrubbed text, so braces in
/// strings/comments cannot desynchronize the tracker.
fn mark_test_regions(scrubbed: &str, nlines: usize) -> Vec<bool> {
    let ch: Vec<char> = scrubbed.chars().collect();
    let n = ch.len();
    // line_at[k] = 1-based line of char k.
    let mut line_at = vec![1usize; n + 1];
    {
        let mut l = 1usize;
        for (k, c) in ch.iter().enumerate() {
            line_at[k] = l;
            if *c == '\n' {
                l += 1;
            }
        }
        line_at[n] = l;
    }
    let mut mask = vec![false; nlines];
    let mut mark = |from: usize, to: usize| {
        let (a, b) = (line_at[from.min(n)], line_at[to.min(n)]);
        for l in a..=b {
            if l >= 1 && l <= nlines {
                mask[l - 1] = true;
            }
        }
    };

    let mut i = 0usize;
    while i < n {
        let c = ch[i];
        // `#[cfg(test)]` attribute (whitespace-insensitive match of the
        // bracket group; `#[cfg(any(test, ...))]` does NOT match).
        if c == '#' {
            let mut j = i + 1;
            while j < n && ch[j].is_whitespace() {
                j += 1;
            }
            if j < n && ch[j] == '[' {
                let mut depth = 0usize;
                let mut k = j;
                while k < n {
                    match ch[k] {
                        '[' => depth += 1,
                        ']' => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    k += 1;
                }
                let text: String =
                    ch[i..=k.min(n - 1)].iter().filter(|c| !c.is_whitespace()).collect();
                if text == "#[cfg(test)]" {
                    let end = item_extent(&ch, k + 1);
                    mark(i, end);
                    i = end + 1;
                    continue;
                }
                i = k + 1;
                continue;
            }
        }
        // `mod tests` (with or without an attribute).
        if is_ident(c) && (i == 0 || !is_ident(ch[i - 1])) {
            let mut j = i;
            while j < n && is_ident(ch[j]) {
                j += 1;
            }
            let word: String = ch[i..j].iter().collect();
            if word == "mod" {
                let mut k = j;
                while k < n && ch[k].is_whitespace() {
                    k += 1;
                }
                let mut m = k;
                while m < n && is_ident(ch[m]) {
                    m += 1;
                }
                let name: String = ch[k..m].iter().collect();
                if name == "tests" {
                    let end = item_extent(&ch, m);
                    mark(i, end);
                    i = end + 1;
                    continue;
                }
            }
            i = j;
            continue;
        }
        i += 1;
    }
    mask
}

/// Extent of the item starting at `start` (after its marker): skips
/// further attributes, then runs to the matching `}` of the item's body,
/// or to a terminating `;` for block-less items (`mod tests;`,
/// `#[cfg(test)] static X: T = v;`). Returns the char index of the
/// item's final char.
fn item_extent(ch: &[char], start: usize) -> usize {
    let n = ch.len();
    let mut i = start;
    // Skip whitespace and subsequent attributes.
    loop {
        while i < n && ch[i].is_whitespace() {
            i += 1;
        }
        if i < n && ch[i] == '#' {
            let mut j = i + 1;
            while j < n && ch[j].is_whitespace() {
                j += 1;
            }
            if j < n && (ch[j] == '[' || (ch[j] == '!' && j + 1 < n && ch[j + 1] == '[')) {
                let mut depth = 0usize;
                let mut k = j;
                while k < n {
                    match ch[k] {
                        '[' => depth += 1,
                        ']' => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    k += 1;
                }
                i = k + 1;
                continue;
            }
        }
        break;
    }
    // Signature scan: first `{` (at paren/bracket depth 0) opens the
    // body; a `;` first means a block-less item.
    let mut pd = 0isize;
    while i < n {
        match ch[i] {
            '(' | '[' => pd += 1,
            ')' | ']' => pd -= 1,
            '{' if pd == 0 => {
                let mut bd = 1usize;
                i += 1;
                while i < n && bd > 0 {
                    match ch[i] {
                        '{' => bd += 1,
                        '}' => bd -= 1,
                        _ => {}
                    }
                    i += 1;
                }
                return i.saturating_sub(1);
            }
            ';' if pd == 0 => return i,
            _ => {}
        }
        i += 1;
    }
    n.saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lex(src: &str) -> LexedFile {
        LexedFile::lex("fixture.rs", "fixture.rs", src)
    }

    /// Old-gate false positive: a panicking token inside a *string* was
    /// flagged by grep. The lexer scrubs it.
    #[test]
    fn string_contents_are_scrubbed() {
        let f = lex("let s = \"call .unwrap() and panic!(now)\";\n");
        assert!(!f.scrubbed.contains("unwrap"));
        assert!(!f.scrubbed.contains("panic"));
        assert_eq!(f.strings.len(), 1);
        assert_eq!(f.strings[0].value, "call .unwrap() and panic!(now)");
    }

    /// Old-gate false negative: `//` inside a string made sed delete the
    /// rest of the line, hiding real code *after* the literal.
    #[test]
    fn comment_marker_inside_string_does_not_eat_code() {
        let f = lex("let u = \"https://host/x\"; maybe.unwrap();\n");
        assert!(f.scrubbed.contains(".unwrap()"), "code after the string must survive");
        assert!(!f.scrubbed.contains("https"));
    }

    /// Old-gate false positive: block comments were never stripped, so a
    /// panicking token in one was flagged.
    #[test]
    fn block_comments_scrubbed_including_nested() {
        let f = lex("/* outer panic!( /* nested .unwrap() */ still comment */ let x = 1;\n");
        assert!(!f.scrubbed.contains("panic"));
        assert!(!f.scrubbed.contains("unwrap"));
        assert!(f.scrubbed.contains("let x = 1;"), "code after the close must survive");
    }

    /// Raw strings with hashes: `"#` inside must not close early; the
    /// contents (with `//` and quotes) are scrubbed.
    #[test]
    fn raw_strings_with_hashes() {
        let f = lex("let r = r##\"has \"# quote // and .unwrap()\"##; after.expect(\"m\");\n");
        assert!(!f.scrubbed.contains(".unwrap()"));
        assert!(f.scrubbed.contains("after.expect("), "code after the raw string survives");
        assert_eq!(f.strings[0].value, "has \"# quote // and .unwrap()");
    }

    /// A raw *identifier* is not a raw string.
    #[test]
    fn raw_identifier_is_code() {
        let f = lex("let r#fn = 1; r#fn.unwrap();\n");
        assert!(f.scrubbed.contains("r#fn.unwrap()"));
    }

    /// Char literals containing `"` and `}` must not open a string or
    /// unbalance brace tracking; byte chars and escapes likewise.
    #[test]
    fn char_literals_with_quote_and_brace() {
        let f = lex(concat!(
            "let a = '\"'; let b = '}'; let c = '\\''; let d = b'\"'; let e = '\\u{7D}';\n",
            "still_code.unwrap();\n",
        ));
        assert!(f.scrubbed.contains("still_code.unwrap()"));
        assert_eq!(f.strings.len(), 0, "no string literal was opened: {:?}", f.strings);
    }

    /// Lifetimes and loop labels are code, not char literals.
    #[test]
    fn lifetimes_and_labels_stay_code() {
        let f = lex("fn f<'a>(x: &'a str) { 'respawn: loop { break 'respawn; } }\n");
        assert!(f.scrubbed.contains("'a str"));
        assert!(f.scrubbed.contains("'respawn: loop"));
    }

    /// Old-gate false negative: everything below the FIRST test module
    /// was deleted, un-checking real code between/after test modules.
    #[test]
    fn multiple_test_modules_and_code_between() {
        let src = concat!(
            "fn real1() { val.unwrap(); }\n",        // 1: code
            "#[cfg(test)]\n",                        // 2: test
            "mod tests { fn t() { x.unwrap(); } }\n", // 3: test
            "fn real2() { val.unwrap(); }\n",        // 4: code (old gate missed this)
            "#[cfg(test)]\n",                        // 5: test
            "mod more_tests {\n",                    // 6
            "    fn u() { y.unwrap(); }\n",          // 7
            "}\n",                                   // 8: test
            "fn real3() {}\n",                       // 9: code
        );
        let f = lex(src);
        let t: Vec<usize> =
            (1..=9).filter(|&l| f.is_test_line(l)).collect();
        assert_eq!(t, vec![2, 3, 5, 6, 7, 8]);
    }

    /// `#[cfg(any(test, ...))]` items are compiled into debug builds —
    /// NOT excluded.
    #[test]
    fn cfg_any_test_is_not_excluded() {
        let f = lex("#[cfg(any(test, feature = \"fault-injection\"))]\nmod active { fn f() {} }\n");
        assert!(!f.is_test_line(1));
        assert!(!f.is_test_line(2));
    }

    /// `mod tests` without an attribute is excluded; a block-less
    /// `#[cfg(test)]` item extends to its `;`.
    #[test]
    fn mod_tests_and_blockless_items() {
        let src = concat!(
            "mod tests { fn t() { a.unwrap(); } }\n", // 1: test
            "#[cfg(test)]\n",                          // 2: test
            "static LOCK: Mutex<()> = Mutex::new(());\n", // 3: test
            "fn real() {}\n",                          // 4: code
        );
        let f = lex(src);
        assert!(f.is_test_line(1));
        assert!(f.is_test_line(2));
        assert!(f.is_test_line(3));
        assert!(!f.is_test_line(4));
    }

    #[test]
    fn comments_and_line_kinds_are_captured() {
        let src = concat!(
            "// SAFETY: fine\n",
            "#[inline]\n",
            "unsafe fn f() {}\n",
            "\n",
        );
        let f = lex(src);
        assert_eq!(f.line_kind(1), LineKind::CommentOnly);
        assert_eq!(f.line_kind(2), LineKind::AttrOnly);
        assert_eq!(f.line_kind(3), LineKind::Code);
        assert_eq!(f.line_kind(4), LineKind::Blank);
        assert!(f.comment_text(1).contains("SAFETY:"));
    }

    #[test]
    fn scrubbed_nontest_blanks_test_lines() {
        let f = lex("fn a() { x.lock(); }\n#[cfg(test)]\nmod tests { fn t() { y.lock(); } }\n");
        let nt = f.scrubbed_nontest();
        assert!(nt.contains("x.lock()"));
        assert!(!nt.contains("y.lock()"));
        assert_eq!(nt.chars().filter(|&c| c == '\n').count(), 3);
    }
}
