//! Tensor metadata: dtypes, shapes, and quantization parameters.
//!
//! Mirrors the TensorFlow Lite tensor model the paper reuses (§4.3.2):
//! tensors carry a dtype, a static shape (dynamic shapes are unsupported,
//! §4.4.2), and optional affine quantization parameters — per-tensor for
//! activations, optionally per-axis (per-output-channel) for weights, as
//! in the TFLite int8 quantization spec.

mod dtype;
mod quant;
mod shape;

pub use dtype::DType;
pub use quant::{QuantParams, QuantizedMultiplier};
pub use shape::Shape;

use crate::error::{Error, Result};

/// Static description of one tensor in a model graph.
///
/// This is the runtime-friendly decoding of a schema tensor record; the
/// interpreter builds one per tensor at initialization time and never
/// mutates it afterward.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    /// Tensor name (diagnostic only; empty string if the model omitted it).
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Static shape. A scalar has an empty dims list.
    pub shape: Shape,
    /// Index into the model buffer table; `None` for activations
    /// (tensors whose storage the memory planner assigns in the arena).
    pub buffer: Option<u32>,
    /// Affine quantization parameters, if the tensor is quantized.
    pub quant: Option<QuantParams>,
    /// Variable tensors persist across invocations (e.g. RNN state).
    pub is_variable: bool,
}

impl TensorMeta {
    /// Total number of elements.
    pub fn num_elements(&self) -> usize {
        self.shape.num_elements()
    }

    /// Storage size in bytes.
    pub fn num_bytes(&self) -> usize {
        self.num_elements() * self.dtype.size_of()
    }

    /// True if this tensor's storage lives in the arena (an activation or
    /// variable tensor) rather than in the serialized model (weights).
    pub fn needs_arena(&self) -> bool {
        self.buffer.is_none()
    }

    /// Returns the per-tensor scale, failing on unquantized tensors.
    pub fn scale(&self) -> Result<f32> {
        self.quant
            .as_ref()
            .map(|q| q.scales[0])
            .ok_or_else(|| Error::InvalidTensor(format!("tensor '{}' is not quantized", self.name)))
    }

    /// Returns the per-tensor zero point, failing on unquantized tensors.
    pub fn zero_point(&self) -> Result<i32> {
        self.quant
            .as_ref()
            .map(|q| q.zero_points[0])
            .ok_or_else(|| Error::InvalidTensor(format!("tensor '{}' is not quantized", self.name)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(dtype: DType, dims: &[i32]) -> TensorMeta {
        TensorMeta {
            name: "t".into(),
            dtype,
            shape: Shape::new(dims.to_vec()),
            buffer: None,
            quant: None,
            is_variable: false,
        }
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(meta(DType::F32, &[2, 3]).num_bytes(), 24);
        assert_eq!(meta(DType::I8, &[2, 3]).num_bytes(), 6);
        assert_eq!(meta(DType::I32, &[]).num_bytes(), 4); // scalar
    }

    #[test]
    fn arena_residency() {
        let mut m = meta(DType::I8, &[4]);
        assert!(m.needs_arena());
        m.buffer = Some(3);
        assert!(!m.needs_arena());
    }

    #[test]
    fn quant_accessors_fail_unquantized() {
        let m = meta(DType::I8, &[4]);
        assert!(m.scale().is_err());
        assert!(m.zero_point().is_err());
    }

    #[test]
    fn quant_accessors_read_first_entry() {
        let mut m = meta(DType::I8, &[4]);
        m.quant = Some(QuantParams::per_tensor(0.5, -3));
        assert_eq!(m.scale().unwrap(), 0.5);
        assert_eq!(m.zero_point().unwrap(), -3);
    }
}
