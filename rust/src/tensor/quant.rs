//! Affine quantization parameters and TFLite-compatible fixed-point math.
//!
//! The paper's models are INT8 TensorFlow Lite models (§5.1); the kernels
//! therefore implement the TFLite quantization spec: `real = scale *
//! (q - zero_point)`, with requantization done in pure integer arithmetic
//! via a 32-bit fixed-point multiplier and a power-of-two shift — no float
//! on the inference path, matching hardware without an FPU (§2.1).
//!
//! The fixed-point helpers mirror gemmlowp/TFLite bit-for-bit
//! (`SaturatingRoundingDoublingHighMul`, `RoundingDivideByPOT`,
//! `MultiplyByQuantizedMultiplier`); the Python exporter uses the same
//! definitions when producing golden vectors, so Rust inference must match
//! them exactly.

/// Affine quantization parameters for a tensor.
///
/// Per-tensor quantization stores one (scale, zero_point) pair; per-axis
/// (per-output-channel weight) quantization stores one pair per slice of
/// `axis`.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantParams {
    /// One scale per quantized slice (length 1 for per-tensor).
    pub scales: Vec<f32>,
    /// One zero point per quantized slice (same length as `scales`).
    pub zero_points: Vec<i32>,
    /// Quantized dimension for per-axis quantization; `None` = per-tensor.
    pub axis: Option<usize>,
}

impl QuantParams {
    /// Per-tensor parameters.
    pub fn per_tensor(scale: f32, zero_point: i32) -> Self {
        QuantParams { scales: vec![scale], zero_points: vec![zero_point], axis: None }
    }

    /// Per-axis parameters (e.g. conv weights quantized per output channel).
    pub fn per_axis(scales: Vec<f32>, zero_points: Vec<i32>, axis: usize) -> Self {
        debug_assert_eq!(scales.len(), zero_points.len());
        QuantParams { scales, zero_points, axis: Some(axis) }
    }

    /// True if this is per-axis quantization.
    pub fn is_per_axis(&self) -> bool {
        self.axis.is_some() && self.scales.len() > 1
    }

    /// Quantize one real value with the per-tensor parameters (index 0).
    pub fn quantize_f32(&self, v: f32) -> i8 {
        let q = (v / self.scales[0]).round() as i32 + self.zero_points[0];
        q.clamp(i8::MIN as i32, i8::MAX as i32) as i8
    }

    /// Dequantize one i8 value with the per-tensor parameters (index 0).
    pub fn dequantize_i8(&self, q: i8) -> f32 {
        self.scales[0] * (q as i32 - self.zero_points[0]) as f32
    }
}

/// A real multiplier encoded as TFLite's 32-bit fixed-point
/// `multiplier * 2^shift` pair, precomputed at prepare time so the invoke
/// path is integer-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QuantizedMultiplier {
    /// Fixed-point mantissa in Q0.31.
    pub multiplier: i32,
    /// Power-of-two exponent; positive = left shift.
    pub shift: i32,
}

impl QuantizedMultiplier {
    /// Encode a real multiplier, rejecting values TFLite's
    /// `QuantizeMultiplier` cannot represent: effective scales must be
    /// finite and non-negative (a negative or NaN/inf scale means the
    /// model's quantization parameters are broken — kernels call this at
    /// prepare time and surface the error there). Exactly 0 encodes as
    /// the zero multiplier, like TFLite.
    pub fn try_from_real(real: f64) -> crate::error::Result<Self> {
        if !real.is_finite() || real < 0.0 {
            return Err(crate::error::Error::InvalidTensor(format!(
                "effective quantized scale must be finite and non-negative, got {real}"
            )));
        }
        Ok(Self::from_real(real))
    }

    /// Encode a real multiplier. Mirrors TFLite's `QuantizeMultiplier`,
    /// including its guards: `shift` is capped at 30 (the single-rounding
    /// `MultiplyByQuantizedMultiplier` cannot honor a larger left shift —
    /// it would overflow the i32 pre-shift; TFLite saturates to
    /// `(i32::MAX, 30)`), and sub-2^-31 magnitudes underflow to the zero
    /// multiplier. Callers with untrusted scales (kernel prepare paths)
    /// should use [`Self::try_from_real`], which additionally rejects
    /// negative/non-finite inputs; this infallible form is for
    /// known-positive values and debug-asserts that precondition.
    pub fn from_real(real: f64) -> Self {
        debug_assert!(
            real.is_finite() && real >= 0.0,
            "invalid effective scale {real} (use try_from_real to surface an error)"
        );
        if real == 0.0 || !real.is_finite() || real < 0.0 {
            return QuantizedMultiplier { multiplier: 0, shift: 0 };
        }
        let (q, mut shift) = frexp(real);
        let mut q_fixed = (q * ((1i64 << 31) as f64)).round() as i64;
        debug_assert!(q_fixed <= 1i64 << 31);
        if q_fixed == 1i64 << 31 {
            q_fixed /= 2;
            shift += 1;
        }
        if shift < -31 {
            // Underflow: the multiplier rounds to zero.
            shift = 0;
            q_fixed = 0;
        }
        if shift > 30 {
            // TFLite: single-rounding MultiplyByQuantizedMultiplier does
            // not support a left shift above 30 (RoundingDivideByPOT /
            // the pre-shift would overflow); saturate.
            shift = 30;
            q_fixed = (1i64 << 31) - 1;
        }
        QuantizedMultiplier { multiplier: q_fixed as i32, shift }
    }

    /// Apply to an i32 accumulator: `round(x * multiplier * 2^shift)` with
    /// TFLite round-to-nearest-ties-away-from-zero-ish semantics.
    #[inline]
    pub fn apply(self, x: i32) -> i32 {
        multiply_by_quantized_multiplier(x, self.multiplier, self.shift)
    }
}

/// `frexp` for f64: returns `(frac, exp)` with `value = frac * 2^exp` and
/// `|frac|` in `[0.5, 1)`. Implemented from bits since libm isn't linked.
pub(crate) fn frexp(value: f64) -> (f64, i32) {
    if value == 0.0 || value.is_nan() || value.is_infinite() {
        return (value, 0);
    }
    let bits = value.to_bits();
    let exp_bits = ((bits >> 52) & 0x7ff) as i64;
    if exp_bits == 0 {
        // Subnormal: scale up by 2^64 first.
        let scaled = value * (2f64).powi(64);
        let (f, e) = frexp(scaled);
        return (f, e - 64);
    }
    let exp = exp_bits - 1022; // unbiased such that frac in [0.5, 1)
    let frac_bits = (bits & !(0x7ffu64 << 52)) | (1022u64 << 52);
    (f64::from_bits(frac_bits), exp as i32)
}

/// gemmlowp `SaturatingRoundingDoublingHighMul`.
#[inline]
pub fn saturating_rounding_doubling_high_mul(a: i32, b: i32) -> i32 {
    let overflow = a == b && a == i32::MIN;
    let ab = a as i64 * b as i64;
    let nudge: i64 = if ab >= 0 { 1 << 30 } else { 1 - (1i64 << 30) };
    // NB: C++ `/` truncates toward zero (gemmlowp divides, it does not
    // shift); Rust `>>` would floor and skew every negative accumulator.
    let result = ((ab + nudge) / (1i64 << 31)) as i32;
    if overflow {
        i32::MAX
    } else {
        result
    }
}

/// gemmlowp `RoundingDivideByPOT` (round-to-nearest, ties up for
/// non-negative, matching TFLite).
#[inline]
pub fn rounding_divide_by_pot(x: i32, exponent: i32) -> i32 {
    debug_assert!((0..=31).contains(&exponent));
    let mask = (1i64 << exponent) - 1;
    let remainder = x as i64 & mask;
    let threshold = (mask >> 1) + if x < 0 { 1 } else { 0 };
    ((x as i64 >> exponent) + i64::from(remainder > threshold)) as i32
}

/// TFLite `MultiplyByQuantizedMultiplier`.
#[inline]
pub fn multiply_by_quantized_multiplier(x: i32, multiplier: i32, shift: i32) -> i32 {
    let left_shift = shift.max(0);
    let right_shift = (-shift).max(0);
    rounding_divide_by_pot(
        saturating_rounding_doubling_high_mul(x.wrapping_shl(left_shift as u32), multiplier),
        right_shift,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frexp_basic() {
        let (f, e) = frexp(8.0);
        assert_eq!((f, e), (0.5, 4));
        let (f, e) = frexp(0.75);
        assert_eq!((f, e), (0.75, 0));
        let (f, e) = frexp(-3.0);
        assert_eq!((f, e), (-0.75, 2));
        let (f, e) = frexp(0.0);
        assert_eq!((f, e), (0.0, 0));
    }

    #[test]
    fn frexp_reconstructs() {
        for &v in &[1e-8, 0.3, 1.0, 7.25, 123456.789, 1e12] {
            let (f, e) = frexp(v);
            assert!((0.5..1.0).contains(&f.abs()), "frac {f} for {v}");
            assert!((f * (2f64).powi(e) - v).abs() < v * 1e-15);
        }
    }

    /// TFLite `QuantizeMultiplier` boundary behavior: shift 30 is the
    /// largest representable left shift; 31 saturates to (i32::MAX, 30).
    #[test]
    fn quantize_multiplier_caps_shift_at_30() {
        // 2^29 → frac 0.5, shift 30: passes through uncapped.
        let q = QuantizedMultiplier::from_real((1u64 << 29) as f64);
        assert_eq!((q.multiplier, q.shift), (1 << 30, 30));
        // 2^30 → frac 0.5, shift 31: capped.
        let q = QuantizedMultiplier::from_real((1u64 << 30) as f64);
        assert_eq!((q.multiplier, q.shift), (i32::MAX, 30));
        // Far larger ratios saturate the same way instead of overflowing
        // RoundingDivideByPOT's 0..=31 exponent / the i32 pre-shift.
        let q = QuantizedMultiplier::from_real(1e18);
        assert_eq!((q.multiplier, q.shift), (i32::MAX, 30));
        // The capped multiplier must still be applicable without
        // tripping RoundingDivideByPOT's exponent bounds.
        let _ = QuantizedMultiplier { multiplier: i32::MAX, shift: 30 }.apply(1);
    }

    /// Subnormal / sub-2^-31 scales underflow to the zero multiplier
    /// (TFLite's `shift < -31` branch), not garbage.
    #[test]
    fn quantize_multiplier_underflows_to_zero() {
        let q = QuantizedMultiplier::from_real(1e-310); // subnormal f64
        assert_eq!((q.multiplier, q.shift), (0, 0));
        assert_eq!(q.apply(1 << 20), 0);
        let q = QuantizedMultiplier::from_real(2f64.powi(-40));
        assert_eq!((q.multiplier, q.shift), (0, 0));
    }

    /// TFLite errors on non-positive / non-finite effective scales;
    /// `try_from_real` mirrors that (0 stays representable as the zero
    /// multiplier, matching `QuantizeMultiplier`'s explicit 0 case).
    #[test]
    fn try_from_real_rejects_invalid_scales() {
        assert!(QuantizedMultiplier::try_from_real(-0.5).is_err());
        assert!(QuantizedMultiplier::try_from_real(f64::NAN).is_err());
        assert!(QuantizedMultiplier::try_from_real(f64::INFINITY).is_err());
        assert!(QuantizedMultiplier::try_from_real(f64::NEG_INFINITY).is_err());
        let q = QuantizedMultiplier::try_from_real(0.0).unwrap();
        assert_eq!((q.multiplier, q.shift), (0, 0));
        let q = QuantizedMultiplier::try_from_real(0.5).unwrap();
        assert_eq!((q.multiplier, q.shift), (1 << 30, 0));
    }

    #[test]
    fn quantize_multiplier_known_values() {
        // multiplier for 0.5 is exactly 2^30 in Q0.31 with shift 0.
        let q = QuantizedMultiplier::from_real(0.5);
        assert_eq!(q.multiplier, 1 << 30);
        assert_eq!(q.shift, 0);
        // 1.0 saturates the mantissa and bumps the shift.
        let q = QuantizedMultiplier::from_real(1.0);
        assert_eq!(q.multiplier, 1 << 30);
        assert_eq!(q.shift, 1);
        // Zero.
        let q = QuantizedMultiplier::from_real(0.0);
        assert_eq!((q.multiplier, q.shift), (0, 0));
    }

    #[test]
    fn apply_matches_real_arithmetic() {
        // For a range of multipliers and accumulators the fixed-point result
        // must be within 1 ulp of round(x * real).
        let reals = [0.0003921568, 0.0117647, 0.25, 0.5, 0.9999, 1.5, 2.0 / 3.0];
        let xs = [-100000, -12345, -1, 0, 1, 7, 12345, 100000, 1 << 20];
        for &r in &reals {
            let qm = QuantizedMultiplier::from_real(r);
            for &x in &xs {
                let got = qm.apply(x);
                let want = (x as f64 * r).round() as i64;
                assert!(
                    (got as i64 - want).abs() <= 1,
                    "real={r} x={x} got={got} want={want}"
                );
            }
        }
    }

    #[test]
    fn srdhm_saturates_min_times_min() {
        assert_eq!(saturating_rounding_doubling_high_mul(i32::MIN, i32::MIN), i32::MAX);
    }

    #[test]
    fn srdhm_identity_with_half() {
        // (1<<30) in Q0.31 represents 0.5; doubling-high-mul by it halves.
        assert_eq!(saturating_rounding_doubling_high_mul(1000, 1 << 30), 500);
        assert_eq!(saturating_rounding_doubling_high_mul(-1000, 1 << 30), -500);
    }

    #[test]
    fn rdbp_rounds_to_nearest() {
        assert_eq!(rounding_divide_by_pot(5, 1), 3); // 2.5 -> 3 (ties up)
        assert_eq!(rounding_divide_by_pot(4, 1), 2);
        // gemmlowp semantics for negatives (threshold gets +1):
        assert_eq!(rounding_divide_by_pot(-5, 1), -3); // -2.5 -> -3 (away)
        assert_eq!(rounding_divide_by_pot(-6, 2), -2); // -1.5 -> -2 (away)
        assert_eq!(rounding_divide_by_pot(-7, 2), -2); // -1.75 -> -2
        assert_eq!(rounding_divide_by_pot(7, 0), 7);
    }

    #[test]
    fn per_tensor_round_trip() {
        let q = QuantParams::per_tensor(0.05, -10);
        for v in [-5.0f32, -0.3, 0.0, 0.72, 4.9] {
            let quantized = q.quantize_f32(v);
            let back = q.dequantize_i8(quantized);
            assert!((back - v).abs() <= 0.05, "v={v} back={back}");
        }
    }

    #[test]
    fn quantize_clamps() {
        let q = QuantParams::per_tensor(0.01, 0);
        assert_eq!(q.quantize_f32(100.0), i8::MAX);
        assert_eq!(q.quantize_f32(-100.0), i8::MIN);
    }

    #[test]
    fn per_axis_flag() {
        let q = QuantParams::per_axis(vec![0.1, 0.2], vec![0, 0], 3);
        assert!(q.is_per_axis());
        let q = QuantParams::per_tensor(0.1, 0);
        assert!(!q.is_per_axis());
    }
}
