//! Element types supported by the framework.

use crate::error::{Error, Result};

/// Element type of a tensor.
///
/// The numeric discriminants are part of the TMF serialization format and
/// must stay in sync with `python/compile/tmf.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum DType {
    /// 32-bit IEEE float.
    F32 = 1,
    /// Signed 8-bit integer (the primary quantized activation/weight type).
    I8 = 2,
    /// Unsigned 8-bit integer (raw sensor data, legacy quantization).
    U8 = 3,
    /// Signed 32-bit integer (biases, shapes, indices).
    I32 = 4,
    /// Signed 64-bit integer.
    I64 = 5,
    /// Boolean, one byte per element.
    Bool = 6,
    /// Signed 16-bit integer (16x8 quantization activations).
    I16 = 7,
}

impl DType {
    /// Decode a serialized dtype tag.
    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            1 => DType::F32,
            2 => DType::I8,
            3 => DType::U8,
            4 => DType::I32,
            5 => DType::I64,
            6 => DType::Bool,
            7 => DType::I16,
            _ => return Err(Error::malformed(format!("unknown dtype tag {v}"))),
        })
    }

    /// Size of one element in bytes.
    pub const fn size_of(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 | DType::Bool => 1,
            DType::I64 => 8,
            DType::I16 => 2,
        }
    }

    /// Human-readable name, used in error messages and bench output.
    pub const fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I8 => "i8",
            DType::U8 => "u8",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::Bool => "bool",
            DType::I16 => "i16",
        }
    }

    /// True for the quantized-integer activation types.
    pub const fn is_quantized_int(self) -> bool {
        matches!(self, DType::I8 | DType::U8 | DType::I16)
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_tags() {
        for tag in 1..=7u8 {
            let d = DType::from_u8(tag).unwrap();
            assert_eq!(d as u8, tag);
        }
    }

    #[test]
    fn rejects_unknown_tags() {
        assert!(DType::from_u8(0).is_err());
        assert!(DType::from_u8(8).is_err());
        assert!(DType::from_u8(255).is_err());
    }

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_of(), 4);
        assert_eq!(DType::I8.size_of(), 1);
        assert_eq!(DType::I16.size_of(), 2);
        assert_eq!(DType::I64.size_of(), 8);
    }

    #[test]
    fn quantized_classification() {
        assert!(DType::I8.is_quantized_int());
        assert!(DType::U8.is_quantized_int());
        assert!(DType::I16.is_quantized_int());
        assert!(!DType::F32.is_quantized_int());
        assert!(!DType::I32.is_quantized_int());
    }
}
