//! Static tensor shapes.
//!
//! TF Micro does not support dynamic shapes (§4.4.2): every dimension is
//! known when the interpreter initializes, which is what makes ahead-of-
//! invoke memory planning possible. `Shape` therefore stores plain
//! positive extents; a scalar is the empty dims list.

use crate::error::{Error, Result};

/// A static tensor shape (row-major / NHWC conventions follow TFLite).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape {
    dims: Vec<i32>,
}

impl Shape {
    /// Build a shape from raw dims. Negative extents are normalized later
    /// by validation; constructors in the schema reader reject them.
    pub fn new(dims: Vec<i32>) -> Self {
        Shape { dims }
    }

    /// Scalar shape.
    pub fn scalar() -> Self {
        Shape { dims: Vec::new() }
    }

    /// Validated constructor: every extent must be >= 1.
    pub fn checked(dims: Vec<i32>) -> Result<Self> {
        for (i, &d) in dims.iter().enumerate() {
            if d < 1 {
                return Err(Error::ShapeMismatch(format!(
                    "dimension {i} has non-positive extent {d} (dynamic shapes are unsupported)"
                )));
            }
        }
        Ok(Shape { dims })
    }

    /// Raw dims.
    pub fn dims(&self) -> &[i32] {
        &self.dims
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Extent of dimension `i`.
    pub fn dim(&self, i: usize) -> i32 {
        self.dims[i]
    }

    /// Total element count (1 for scalars).
    pub fn num_elements(&self) -> usize {
        self.dims.iter().map(|&d| d.max(0) as usize).product()
    }

    /// Interpret as NHWC, failing unless rank is 4.
    pub fn as_nhwc(&self) -> Result<(usize, usize, usize, usize)> {
        if self.rank() != 4 {
            return Err(Error::ShapeMismatch(format!(
                "expected rank-4 NHWC shape, got rank {} ({:?})",
                self.rank(),
                self.dims
            )));
        }
        Ok((
            self.dims[0] as usize,
            self.dims[1] as usize,
            self.dims[2] as usize,
            self.dims[3] as usize,
        ))
    }

    /// Row-major strides in elements.
    pub fn strides(&self) -> Vec<usize> {
        let mut strides = vec![1usize; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.dims[i + 1] as usize;
        }
        strides
    }

    /// Flatten to `[outer, last]`, the view fully-connected kernels use.
    pub fn as_matrix(&self) -> (usize, usize) {
        if self.dims.is_empty() {
            return (1, 1);
        }
        let last = *self.dims.last().unwrap() as usize;
        (self.num_elements() / last.max(1), last)
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, "x")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_has_one_element() {
        assert_eq!(Shape::scalar().num_elements(), 1);
        assert_eq!(Shape::scalar().rank(), 0);
    }

    #[test]
    fn element_counts() {
        assert_eq!(Shape::new(vec![2, 3, 4]).num_elements(), 24);
        assert_eq!(Shape::new(vec![1]).num_elements(), 1);
    }

    #[test]
    fn checked_rejects_nonpositive() {
        assert!(Shape::checked(vec![2, 0]).is_err());
        assert!(Shape::checked(vec![-1, 3]).is_err());
        assert!(Shape::checked(vec![2, 3]).is_ok());
    }

    #[test]
    fn nhwc_unpack() {
        let s = Shape::new(vec![1, 96, 96, 3]);
        assert_eq!(s.as_nhwc().unwrap(), (1, 96, 96, 3));
        assert!(Shape::new(vec![2, 3]).as_nhwc().is_err());
    }

    #[test]
    fn strides_row_major() {
        let s = Shape::new(vec![2, 3, 4]);
        assert_eq!(s.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn matrix_view() {
        assert_eq!(Shape::new(vec![2, 3, 4]).as_matrix(), (6, 4));
        assert_eq!(Shape::new(vec![5]).as_matrix(), (1, 5));
        assert_eq!(Shape::scalar().as_matrix(), (1, 1));
    }

    #[test]
    fn display() {
        assert_eq!(Shape::new(vec![1, 2, 3]).to_string(), "[1x2x3]");
    }
}
