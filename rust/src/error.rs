//! Error type for the whole framework.
//!
//! TF Micro reports failures through application-level status codes rather
//! than aborting (paper §4.4.1: "If an allocation takes up too much space,
//! we raise an application-level error"). We mirror that with a single
//! non-panicking error enum; the interpreter never unwinds across the
//! kernel boundary.
//!
//! Display/Error impls are hand-written rather than derived so the crate
//! stays dependency-free and builds offline.

/// Framework-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// All failure modes surfaced by the framework.
#[derive(Debug)]
pub enum Error {
    /// The caller-supplied arena could not satisfy an allocation.
    /// Mirrors the paper's arena-exhaustion application error (§4.4.1).
    ArenaExhausted {
        /// Bytes requested by the failing allocation.
        requested: usize,
        /// Bytes still unallocated in the arena.
        available: usize,
        /// Total arena capacity.
        capacity: usize,
        /// Which arena section the allocation targeted ("head", "tail", "temp").
        section: &'static str,
    },

    /// Allocation was attempted outside the initialization phase
    /// (the framework forbids allocation during `invoke`, §4.4.1).
    AllocAfterInit(&'static str),

    /// The serialized model failed validation.
    MalformedModel(String),

    /// The model references an operator the resolver does not provide
    /// (the OpResolver links only registered kernels, §4.1).
    UnsupportedOp(String),

    /// The resolver's fixed capacity was exceeded.
    ResolverFull(usize),

    /// A kernel rejected its inputs during the prepare phase.
    PrepareFailed {
        /// Index of the failing operation in the model's execution order.
        op_index: usize,
        /// Builtin name of the failing operator.
        op_name: &'static str,
        /// Human-readable description of the rejection.
        reason: String,
    },

    /// A kernel failed during evaluation.
    InvokeFailed {
        /// Index of the failing operation in the model's execution order.
        op_index: usize,
        /// Builtin name of the failing operator.
        op_name: &'static str,
        /// Human-readable description of the failure.
        reason: String,
    },

    /// Tensor index out of range or of the wrong type.
    InvalidTensor(String),

    /// Shape or dtype mismatch.
    ShapeMismatch(String),

    /// The memory planner could not produce a plan.
    PlanFailed(String),

    /// Error from the XLA/PJRT runtime (optimized-kernel path only;
    /// the pure-interpreter path never touches this).
    Xla(String),

    /// The serving layer rejected or dropped a request.
    Serving(String),

    /// A non-blocking or timed `submit` found the request queue full and
    /// shed the request instead of waiting (load shedding).
    QueueFull {
        /// Id of the shed request.
        id: u64,
    },

    /// The serving circuit breaker is open (worker respawn budget
    /// exhausted or the whole fleet died); `submit` rejects fast.
    CircuitOpen {
        /// Id of the rejected request.
        id: u64,
    },

    /// A request's input length does not match the model's input tensor,
    /// caught at `submit` so it can never panic or truncate in a worker.
    InvalidInput {
        /// Id of the rejected request.
        id: u64,
        /// Element count the model's input tensor expects.
        expected: usize,
        /// Element count the request carried.
        got: usize,
    },

    /// A model registry `publish` was rejected before promotion — the
    /// new version failed to prepare or failed canary validation. The
    /// previously live version keeps serving.
    PublishRejected {
        /// Name of the rejected version.
        version: String,
        /// Lifecycle stage that rejected it ("prepare" or "canary").
        stage: &'static str,
        /// Human-readable description of the rejection.
        reason: String,
    },

    /// I/O error loading a model or artifact from disk (host-side tooling
    /// only; the embedded-style API works from in-memory byte slices).
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::ArenaExhausted { requested, available, capacity, section } => write!(
                f,
                "arena exhausted: requested {requested} bytes ({section}), {available} available of {capacity}"
            ),
            Error::AllocAfterInit(what) => {
                write!(f, "allocation attempted after initialization phase: {what}")
            }
            Error::MalformedModel(msg) => write!(f, "malformed model: {msg}"),
            Error::UnsupportedOp(op) => {
                write!(f, "unsupported operator: {op} (not registered in the OpResolver)")
            }
            Error::ResolverFull(cap) => write!(f, "op resolver full: capacity {cap}"),
            Error::PrepareFailed { op_index, op_name, reason } => {
                write!(f, "prepare failed for op #{op_index} ({op_name}): {reason}")
            }
            Error::InvokeFailed { op_index, op_name, reason } => {
                write!(f, "invoke failed for op #{op_index} ({op_name}): {reason}")
            }
            Error::InvalidTensor(msg) => write!(f, "invalid tensor access: {msg}"),
            Error::ShapeMismatch(msg) => write!(f, "shape/type mismatch: {msg}"),
            Error::PlanFailed(msg) => write!(f, "memory planning failed: {msg}"),
            Error::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            Error::Serving(msg) => write!(f, "serving error: {msg}"),
            Error::QueueFull { id } => {
                write!(f, "serving queue full: request {id} shed at submit")
            }
            Error::CircuitOpen { id } => write!(
                f,
                "serving circuit breaker open: request {id} rejected (respawn budget exhausted)"
            ),
            Error::InvalidInput { id, expected, got } => write!(
                f,
                "invalid request input: request {id} carries {got} elements, model expects {expected}"
            ),
            Error::PublishRejected { version, stage, reason } => write!(
                f,
                "publish of model version '{version}' rejected at {stage}: {reason}"
            ),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand used by schema validation code.
    pub fn malformed(msg: impl Into<String>) -> Self {
        Error::MalformedModel(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_error_displays_fields() {
        let e = Error::ArenaExhausted { requested: 128, available: 64, capacity: 1024, section: "head" };
        let s = e.to_string();
        assert!(s.contains("128"));
        assert!(s.contains("64"));
        assert!(s.contains("head"));
    }

    #[test]
    fn malformed_helper() {
        let e = Error::malformed("bad magic");
        assert!(matches!(e, Error::MalformedModel(_)));
        assert!(e.to_string().contains("bad magic"));
    }
}
