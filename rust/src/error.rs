//! Error type for the whole framework.
//!
//! TF Micro reports failures through application-level status codes rather
//! than aborting (paper §4.4.1: "If an allocation takes up too much space,
//! we raise an application-level error"). We mirror that with a single
//! non-panicking error enum; the interpreter never unwinds across the
//! kernel boundary.

use thiserror::Error;

/// Framework-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// All failure modes surfaced by the framework.
#[derive(Debug, Error)]
pub enum Error {
    /// The caller-supplied arena could not satisfy an allocation.
    /// Mirrors the paper's arena-exhaustion application error (§4.4.1).
    #[error("arena exhausted: requested {requested} bytes ({section}), {available} available of {capacity}")]
    ArenaExhausted {
        /// Bytes requested by the failing allocation.
        requested: usize,
        /// Bytes still unallocated in the arena.
        available: usize,
        /// Total arena capacity.
        capacity: usize,
        /// Which arena section the allocation targeted ("head", "tail", "temp").
        section: &'static str,
    },

    /// Allocation was attempted outside the initialization phase
    /// (the framework forbids allocation during `invoke`, §4.4.1).
    #[error("allocation attempted after initialization phase: {0}")]
    AllocAfterInit(&'static str),

    /// The serialized model failed validation.
    #[error("malformed model: {0}")]
    MalformedModel(String),

    /// The model references an operator the resolver does not provide
    /// (the OpResolver links only registered kernels, §4.1).
    #[error("unsupported operator: {0} (not registered in the OpResolver)")]
    UnsupportedOp(String),

    /// The resolver's fixed capacity was exceeded.
    #[error("op resolver full: capacity {0}")]
    ResolverFull(usize),

    /// A kernel rejected its inputs during the prepare phase.
    #[error("prepare failed for op #{op_index} ({op_name}): {reason}")]
    PrepareFailed {
        /// Index of the failing operation in the model's execution order.
        op_index: usize,
        /// Builtin name of the failing operator.
        op_name: &'static str,
        /// Human-readable description of the rejection.
        reason: String,
    },

    /// A kernel failed during evaluation.
    #[error("invoke failed for op #{op_index} ({op_name}): {reason}")]
    InvokeFailed {
        /// Index of the failing operation in the model's execution order.
        op_index: usize,
        /// Builtin name of the failing operator.
        op_name: &'static str,
        /// Human-readable description of the failure.
        reason: String,
    },

    /// Tensor index out of range or of the wrong type.
    #[error("invalid tensor access: {0}")]
    InvalidTensor(String),

    /// Shape or dtype mismatch.
    #[error("shape/type mismatch: {0}")]
    ShapeMismatch(String),

    /// The memory planner could not produce a plan.
    #[error("memory planning failed: {0}")]
    PlanFailed(String),

    /// Error from the XLA/PJRT runtime (optimized-kernel path only;
    /// the pure-interpreter path never touches this).
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// The serving layer rejected or dropped a request.
    #[error("serving error: {0}")]
    Serving(String),

    /// I/O error loading a model or artifact from disk (host-side tooling
    /// only; the embedded-style API works from in-memory byte slices).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand used by schema validation code.
    pub fn malformed(msg: impl Into<String>) -> Self {
        Error::MalformedModel(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_error_displays_fields() {
        let e = Error::ArenaExhausted { requested: 128, available: 64, capacity: 1024, section: "head" };
        let s = e.to_string();
        assert!(s.contains("128"));
        assert!(s.contains("64"));
        assert!(s.contains("head"));
    }

    #[test]
    fn malformed_helper() {
        let e = Error::malformed("bad magic");
        assert!(matches!(e, Error::MalformedModel(_)));
        assert!(e.to_string().contains("bad magic"));
    }
}
