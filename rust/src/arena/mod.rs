//! Static memory management from a caller-supplied arena (§4.4).
//!
//! The framework performs **no heap allocation after initialization**: the
//! application hands the interpreter one contiguous memory arena, all
//! buffers (runtime tensors, persistent metadata, scratch) are carved out
//! of it during `allocate_tensors`, and any allocation attempted during
//! `invoke` is an error. This mirrors the paper exactly (§4.4.1): arenas
//! avoid heap fragmentation killing long-running always-on applications.
//!
//! The allocator is the paper's **two-stack scheme** (Figure 3): one stack
//! grows up from the lowest address for *function-lifetime* data (the
//! "head": intermediate tensors, init-time temporaries), one grows down
//! from the highest address for *interpreter-lifetime* data (the "tail":
//! tensor metadata, variable tensors, persistent scratch). The space
//! between the stacks serves temporary allocations during memory planning.
//! When the pointers cross, the arena is exhausted and an application-level
//! error is raised.

mod two_stack;

pub use two_stack::{ArenaUsage, Section, TwoStackAllocator, DEFAULT_ALIGN};

/// An owned, heap-backed arena buffer (host-side convenience — on a real
/// MCU the application supplies a static array instead; every framework
/// API also accepts a plain `&mut [u8]`).
pub struct Arena {
    buf: Vec<u8>,
}

impl Arena {
    /// Allocate a zeroed arena of `size` bytes.
    pub fn new(size: usize) -> Self {
        Arena { buf: vec![0u8; size] }
    }

    /// Capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Mutable view of the backing storage.
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        &mut self.buf
    }

    /// Immutable view of the backing storage.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }
}

impl std::fmt::Debug for Arena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Arena({} bytes)", self.buf.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_is_zeroed() {
        let a = Arena::new(64);
        assert_eq!(a.capacity(), 64);
        assert!(a.as_slice().iter().all(|&b| b == 0));
    }
}
