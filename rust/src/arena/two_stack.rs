//! The two-stack arena allocator (paper §4.4.1, Figure 3).
//!
//! The allocator tracks **offsets only** — it never holds references into
//! the arena storage. The interpreter combines the offsets it returns with
//! the caller's `&mut [u8]` to address tensor data; keeping the allocator
//! reference-free sidesteps aliasing headaches and matches how the C++
//! original works (pointer arithmetic over a `uint8_t*`).
//!
//! Lifetimes, as in the paper:
//!
//! * **Tail** (grows down from the top): interpreter-lifetime data —
//!   decoded tensor metadata, kernel user data, variable tensors,
//!   persistent scratch. Never freed.
//! * **Head** (grows up from the bottom): function-lifetime data — the
//!   planned intermediate-tensor region lives here; an application may
//!   reuse the head between invocations (§4.4.1 last ¶).
//! * **Temp** (between the stacks): allocations alive only during memory
//!   planning; must be reset before initialization finishes.
//!
//! When head and tail would cross, allocation fails with an
//! application-level `Error::ArenaExhausted`.

use crate::error::{Error, Result};

/// Default buffer alignment, matching TF Micro's 16-byte arena alignment.
pub const DEFAULT_ALIGN: usize = 16;

/// Which arena section an allocation targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Section {
    /// Function-lifetime stack (grows up).
    Head,
    /// Interpreter-lifetime stack (grows down).
    Tail,
    /// Planning-time temporaries between the stacks.
    Temp,
}

/// Arena accounting snapshot — the numbers Table 2 of the paper reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ArenaUsage {
    /// Bytes allocated with interpreter lifetime (tail stack).
    pub persistent: usize,
    /// Of `persistent`: bytes owned by kernel persistent buffers
    /// (packed weights, folded biases) requested via
    /// `PrepareContext::request_persistent`. Reported separately so the
    /// Table-2-style accounting stays honest about what prepare-time
    /// precomputation costs. At the interpreter level this line (and the
    /// persistent/total lines) additionally includes off-arena bytes
    /// accelerated kernels charge via
    /// `PrepareContext::charge_kernel_external` (XLA staged literals),
    /// so the report is the true init-time footprint.
    pub kernel_buffers: usize,
    /// Bytes allocated with function lifetime (head high watermark).
    pub nonpersistent: usize,
    /// Peak simultaneous use (head watermark + tail watermark).
    pub total: usize,
    /// Arena capacity.
    pub capacity: usize,
}

/// Offset-based two-stack allocator over a fixed capacity.
#[derive(Debug, Clone)]
pub struct TwoStackAllocator {
    capacity: usize,
    /// First free byte of the head stack (grows up).
    head: usize,
    /// First used byte of the tail stack (grows down).
    tail: usize,
    /// Current temp allocation cursor (grows up from `head`); `head` itself
    /// is not moved by temp allocations.
    temp: usize,
    /// Number of outstanding temp allocations.
    temp_count: usize,
    /// High watermark of the head stack.
    head_watermark: usize,
    /// High watermark of head+temp (planning-time peak).
    temp_watermark: usize,
    /// Low watermark of the tail stack.
    tail_watermark: usize,
    /// Tail bytes (including alignment slack) consumed by kernel
    /// persistent buffers, tracked for the ArenaUsage breakdown.
    kernel_buffers: usize,
    /// Set once initialization completes; further allocation is an error.
    sealed: bool,
}

fn align_up(v: usize, align: usize) -> usize {
    debug_assert!(align.is_power_of_two());
    (v + align - 1) & !(align - 1)
}

fn align_down(v: usize, align: usize) -> usize {
    debug_assert!(align.is_power_of_two());
    v & !(align - 1)
}

impl TwoStackAllocator {
    /// Create an allocator over `capacity` bytes.
    pub fn new(capacity: usize) -> Self {
        TwoStackAllocator {
            capacity,
            head: 0,
            tail: capacity,
            temp: 0,
            temp_count: 0,
            head_watermark: 0,
            temp_watermark: 0,
            tail_watermark: capacity,
            kernel_buffers: 0,
            sealed: false,
        }
    }

    fn exhausted(&self, requested: usize, section: &'static str) -> Error {
        Error::ArenaExhausted {
            requested,
            available: self.tail.saturating_sub(self.head.max(self.temp)),
            capacity: self.capacity,
            section,
        }
    }

    /// Allocate `size` bytes with interpreter lifetime (tail stack).
    pub fn alloc_tail(&mut self, size: usize, align: usize) -> Result<usize> {
        if self.sealed {
            return Err(Error::AllocAfterInit("tail allocation"));
        }
        let new_tail = align_down(self.tail.checked_sub(size).ok_or_else(|| self.exhausted(size, "tail"))?, align);
        if new_tail < self.head.max(self.temp) {
            return Err(self.exhausted(size, "tail"));
        }
        self.tail = new_tail;
        self.tail_watermark = self.tail_watermark.min(new_tail);
        Ok(new_tail)
    }

    /// Allocate a kernel persistent buffer: identical to [`alloc_tail`]
    /// (interpreter lifetime) but tagged so `usage()` can report
    /// kernel-owned bytes as their own line.
    ///
    /// [`alloc_tail`]: TwoStackAllocator::alloc_tail
    pub fn alloc_tail_kernel(&mut self, size: usize, align: usize) -> Result<usize> {
        let before = self.tail;
        let off = self.alloc_tail(size, align)?;
        self.kernel_buffers += before - off;
        Ok(off)
    }

    /// Allocate `size` bytes with function lifetime (head stack).
    pub fn alloc_head(&mut self, size: usize, align: usize) -> Result<usize> {
        if self.sealed {
            return Err(Error::AllocAfterInit("head allocation"));
        }
        if self.temp_count > 0 {
            return Err(Error::PlanFailed(
                "head allocation while temp allocations are outstanding".into(),
            ));
        }
        let off = align_up(self.head, align);
        let end = off.checked_add(size).ok_or_else(|| self.exhausted(size, "head"))?;
        if end > self.tail {
            return Err(self.exhausted(size, "head"));
        }
        self.head = end;
        self.temp = self.temp.max(end);
        self.head_watermark = self.head_watermark.max(end);
        self.temp_watermark = self.temp_watermark.max(end);
        Ok(off)
    }

    /// Ensure the head section spans at least `size` bytes, without
    /// assigning individual offsets (used for the planner-managed
    /// intermediate-tensor region).
    pub fn reserve_head(&mut self, size: usize, align: usize) -> Result<usize> {
        self.alloc_head(size, align)
    }

    /// Reset the head stack, discarding all function-lifetime allocations
    /// (legal between invocations; the paper's "reuse the arena's
    /// function-lifetime section in between evaluation calls").
    pub fn reset_head(&mut self) {
        self.head = 0;
        self.temp = 0;
    }

    /// Allocate a planning-time temporary in the gap between the stacks.
    pub fn alloc_temp(&mut self, size: usize, align: usize) -> Result<usize> {
        if self.sealed {
            return Err(Error::AllocAfterInit("temp allocation"));
        }
        let off = align_up(self.temp.max(self.head), align);
        let end = off.checked_add(size).ok_or_else(|| self.exhausted(size, "temp"))?;
        if end > self.tail {
            return Err(self.exhausted(size, "temp"));
        }
        self.temp = end;
        self.temp_count += 1;
        self.temp_watermark = self.temp_watermark.max(end);
        Ok(off)
    }

    /// Release all temporaries (they deallocate together, stack-style).
    pub fn reset_temp(&mut self) {
        self.temp = self.head;
        self.temp_count = 0;
    }

    /// Seal the allocator at the end of initialization: all further
    /// allocation attempts fail (§4.4.1: "No allocation ... is possible
    /// during model invocation").
    pub fn seal(&mut self) {
        self.sealed = true;
    }

    /// True once sealed.
    pub fn is_sealed(&self) -> bool {
        self.sealed
    }

    /// Bytes remaining between the stacks.
    pub fn available(&self) -> usize {
        self.tail.saturating_sub(self.head.max(self.temp))
    }

    /// Current head cursor.
    pub fn head_used(&self) -> usize {
        self.head
    }

    /// Bytes allocated from the tail (persistent section size).
    pub fn tail_used(&self) -> usize {
        self.capacity - self.tail
    }

    /// Usage snapshot (Table 2 numbers).
    pub fn usage(&self) -> ArenaUsage {
        ArenaUsage {
            persistent: self.capacity - self.tail_watermark,
            kernel_buffers: self.kernel_buffers,
            nonpersistent: self.head_watermark,
            total: self.head_watermark + (self.capacity - self.tail_watermark),
            capacity: self.capacity,
        }
    }

    /// Peak use including planning-time temporaries — the minimum arena
    /// size that would have succeeded.
    pub fn peak_including_temp(&self) -> usize {
        self.temp_watermark + (self.capacity - self.tail_watermark)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_grows_up_tail_grows_down() {
        let mut a = TwoStackAllocator::new(1024);
        let h0 = a.alloc_head(100, 16).unwrap();
        let h1 = a.alloc_head(50, 16).unwrap();
        assert_eq!(h0, 0);
        assert_eq!(h1, 112); // 100 aligned up to 112
        let t0 = a.alloc_tail(64, 16).unwrap();
        let t1 = a.alloc_tail(32, 16).unwrap();
        assert!(t0 > t1, "tail allocations move downward");
        assert_eq!(t0 % 16, 0);
        assert_eq!(t1 % 16, 0);
        assert_eq!(t0, 1024 - 64);
    }

    #[test]
    fn crossing_pointers_exhaust() {
        let mut a = TwoStackAllocator::new(256);
        a.alloc_head(128, 16).unwrap();
        a.alloc_tail(64, 16).unwrap();
        let err = a.alloc_head(128, 16).unwrap_err();
        assert!(matches!(err, Error::ArenaExhausted { .. }), "{err}");
        // Tail exhaustion too.
        let err = a.alloc_tail(128, 16).unwrap_err();
        assert!(matches!(err, Error::ArenaExhausted { .. }));
    }

    #[test]
    fn head_reset_reuses_space() {
        let mut a = TwoStackAllocator::new(256);
        a.alloc_head(200, 16).unwrap();
        assert!(a.alloc_head(200, 16).is_err());
        a.reset_head();
        assert!(a.alloc_head(200, 16).is_ok());
        // Watermark remembers the peak.
        assert_eq!(a.usage().nonpersistent, 200);
    }

    #[test]
    fn temp_allocations_between_stacks() {
        let mut a = TwoStackAllocator::new(1024);
        a.alloc_head(100, 16).unwrap();
        a.alloc_tail(100, 16).unwrap();
        let t = a.alloc_temp(200, 16).unwrap();
        assert!(t >= 100 && t + 200 <= 924);
        // Head allocation while temps outstanding is a planning bug.
        assert!(a.alloc_head(16, 16).is_err());
        a.reset_temp();
        assert!(a.alloc_head(16, 16).is_ok());
        // Temp peak is visible in peak_including_temp but not in usage().
        assert!(a.peak_including_temp() >= 300);
        // head cursor was 100, aligned to 112, +16 = 128 watermark.
        assert_eq!(a.usage().nonpersistent, 128);
    }

    #[test]
    fn temp_exhaustion() {
        let mut a = TwoStackAllocator::new(128);
        a.alloc_tail(64, 16).unwrap();
        assert!(a.alloc_temp(128, 16).is_err());
    }

    #[test]
    fn sealed_rejects_all_allocation() {
        let mut a = TwoStackAllocator::new(256);
        a.alloc_head(16, 16).unwrap();
        a.seal();
        assert!(matches!(a.alloc_head(1, 1), Err(Error::AllocAfterInit(_))));
        assert!(matches!(a.alloc_tail(1, 1), Err(Error::AllocAfterInit(_))));
        assert!(matches!(a.alloc_temp(1, 1), Err(Error::AllocAfterInit(_))));
    }

    #[test]
    fn usage_snapshot() {
        let mut a = TwoStackAllocator::new(1000);
        a.alloc_head(100, 4).unwrap();
        a.alloc_tail(200, 4).unwrap();
        let u = a.usage();
        assert_eq!(u.nonpersistent, 100);
        assert_eq!(u.persistent, 200);
        assert_eq!(u.total, 300);
        assert_eq!(u.capacity, 1000);
    }

    #[test]
    fn kernel_buffers_tracked_within_persistent() {
        let mut a = TwoStackAllocator::new(1024);
        a.alloc_tail(100, 4).unwrap();
        a.alloc_tail_kernel(64, 16).unwrap();
        a.alloc_tail_kernel(32, 16).unwrap();
        let u = a.usage();
        assert!(u.kernel_buffers >= 96, "alignment slack counts: {}", u.kernel_buffers);
        assert!(u.kernel_buffers <= u.persistent);
        // Plain tail allocations are not charged as kernel buffers.
        assert!(u.persistent >= u.kernel_buffers + 100);
    }

    #[test]
    fn zero_sized_allocations_are_fine() {
        let mut a = TwoStackAllocator::new(64);
        let h = a.alloc_head(0, 16).unwrap();
        let t = a.alloc_tail(0, 16).unwrap();
        assert_eq!(h, 0);
        assert_eq!(t, 64);
    }

    #[test]
    fn alignment_respected() {
        let mut a = TwoStackAllocator::new(4096);
        for align in [1usize, 2, 4, 8, 16, 32, 64] {
            let h = a.alloc_head(3, align).unwrap();
            assert_eq!(h % align, 0, "head align {align}");
            let t = a.alloc_tail(3, align).unwrap();
            assert_eq!(t % align, 0, "tail align {align}");
        }
    }

    // Property-style test: random interleavings never violate invariants.
    #[test]
    fn property_random_interleavings_preserve_invariants() {
        let mut rng = crate::testutil::Rng::seeded(0xA1EA);
        for _ in 0..200 {
            let capacity = 64 + (rng.next_usize() % 4096);
            let mut a = TwoStackAllocator::new(capacity);
            let mut temps_live = false;
            for _ in 0..64 {
                let size = rng.next_usize() % 256;
                let align = 1usize << (rng.next_usize() % 6);
                match rng.next_usize() % 5 {
                    0 if !temps_live => {
                        if let Ok(off) = a.alloc_head(size, align) {
                            assert_eq!(off % align, 0);
                            assert!(off + size <= capacity);
                        }
                    }
                    1 => {
                        if let Ok(off) = a.alloc_tail(size, align) {
                            assert_eq!(off % align, 0);
                            assert!(off + size <= capacity);
                        }
                    }
                    2 => {
                        if let Ok(off) = a.alloc_temp(size, align) {
                            temps_live = true;
                            assert_eq!(off % align, 0);
                            assert!(off + size <= capacity);
                        }
                    }
                    3 => {
                        a.reset_temp();
                        temps_live = false;
                    }
                    _ => {
                        if !temps_live {
                            a.reset_head();
                        }
                    }
                }
                // Core invariant: stacks never cross.
                assert!(a.head_used() <= capacity - a.tail_used());
                let u = a.usage();
                assert!(u.total <= u.capacity + u.nonpersistent); // watermarks are monotone
            }
        }
    }
}
