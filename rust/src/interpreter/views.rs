//! Typed views over a tensor's storage, returned by the interpreter's
//! input/output accessors (§4.1 step 4: "the application retrieves
//! pointers to the memory regions that represent the model inputs and
//! populates them with values").

use crate::error::{Error, Result};
use crate::ops::{cast_f32, cast_f32_mut, cast_i8, cast_i8_mut, cast_i32};
use crate::tensor::{DType, TensorMeta};

/// Read-only view of one tensor.
pub struct TensorView<'a> {
    /// Tensor metadata (shape, dtype, quantization).
    pub meta: &'a TensorMeta,
    pub(crate) bytes: &'a [u8],
}

impl<'a> TensorView<'a> {
    /// Raw storage bytes.
    pub fn bytes(&self) -> &'a [u8] {
        self.bytes
    }

    /// View as i8 elements.
    pub fn as_i8(&self) -> Result<&'a [i8]> {
        self.expect(DType::I8)?;
        Ok(cast_i8(self.bytes))
    }

    /// View as f32 elements.
    pub fn as_f32(&self) -> Result<&'a [f32]> {
        self.expect(DType::F32)?;
        cast_f32(self.bytes)
    }

    /// View as i32 elements.
    pub fn as_i32(&self) -> Result<&'a [i32]> {
        self.expect(DType::I32)?;
        cast_i32(self.bytes)
    }

    /// Dequantize an i8 tensor into a fresh Vec (host-side convenience).
    pub fn dequantized(&self) -> Result<Vec<f32>> {
        let q = self
            .meta
            .quant
            .as_ref()
            .ok_or_else(|| Error::InvalidTensor(format!("'{}' is not quantized", self.meta.name)))?;
        Ok(self.as_i8()?.iter().map(|&v| q.dequantize_i8(v)).collect())
    }

    fn expect(&self, want: DType) -> Result<()> {
        if self.meta.dtype != want {
            return Err(Error::ShapeMismatch(format!(
                "tensor '{}' is {}, requested {}",
                self.meta.name, self.meta.dtype, want
            )));
        }
        Ok(())
    }
}

/// Mutable view of one tensor.
pub struct TensorViewMut<'a> {
    /// Tensor metadata.
    pub meta: &'a TensorMeta,
    pub(crate) bytes: &'a mut [u8],
}

impl<'a> TensorViewMut<'a> {
    /// Raw mutable storage bytes.
    pub fn bytes_mut(&mut self) -> &mut [u8] {
        self.bytes
    }

    /// Mutable i8 elements.
    pub fn as_i8_mut(&mut self) -> Result<&mut [i8]> {
        self.expect(DType::I8)?;
        Ok(cast_i8_mut(self.bytes))
    }

    /// Mutable f32 elements.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        self.expect(DType::F32)?;
        cast_f32_mut(self.bytes)
    }

    /// Copy i8 data in, checking the length.
    pub fn copy_from_i8(&mut self, src: &[i8]) -> Result<()> {
        let dst = self.as_i8_mut()?;
        if dst.len() != src.len() {
            return Err(Error::ShapeMismatch(format!(
                "copy_from_i8: {} elements into tensor of {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    /// Copy f32 data in, checking the length.
    pub fn copy_from_f32(&mut self, src: &[f32]) -> Result<()> {
        let dst = self.as_f32_mut()?;
        if dst.len() != src.len() {
            return Err(Error::ShapeMismatch(format!(
                "copy_from_f32: {} elements into tensor of {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    /// Fill an i8 tensor with one value.
    pub fn fill_i8(&mut self, v: i8) {
        self.bytes.fill(v as u8);
    }

    /// Quantize float data in using the tensor's own parameters.
    pub fn quantize_from_f32(&mut self, src: &[f32]) -> Result<()> {
        let q = self
            .meta
            .quant
            .clone()
            .ok_or_else(|| Error::InvalidTensor(format!("'{}' is not quantized", self.meta.name)))?;
        let dst = self.as_i8_mut()?;
        if dst.len() != src.len() {
            return Err(Error::ShapeMismatch(format!(
                "quantize_from_f32: {} elements into tensor of {}",
                src.len(),
                dst.len()
            )));
        }
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = q.quantize_f32(v);
        }
        Ok(())
    }

    fn expect(&self, want: DType) -> Result<()> {
        if self.meta.dtype != want {
            return Err(Error::ShapeMismatch(format!(
                "tensor '{}' is {}, requested {}",
                self.meta.name, self.meta.dtype, want
            )));
        }
        Ok(())
    }
}
