//! Shared immutable model state + cheap per-worker execution state
//! (ROADMAP direction 2; the MicroFlow static-model/mutable-state split).
//!
//! A [`MicroInterpreter`] owns everything — packed weights, folded
//! biases, memory plan, activations — so N workers serving M models pay
//! O(N×M) populate passes (XLA compile per worker!) and O(N×M) resident
//! packed-weight bytes. The paper's §4.6 threading model only requires
//! the *mutable* state to be private per worker; everything the populate
//! pass produces is read-only afterwards and can be shared.
//!
//! [`PreparedModel`] is that read-only half, built once and handed out
//! behind `Arc`: resolved kernels, prepared op data, the sealed memory
//! plan, and one populate pass worth of persistent kernel buffers
//! (repacked weights, folded biases, VNNI side tables, compiled XLA
//! executables). [`ExecState`] is the mutable half a worker owns
//! privately: one zeroed activation/scratch buffer sized by the plan,
//! its own variable-tensor storage, and per-op degrade flags so one
//! worker's offload failure never poisons its siblings.
//!
//! Fleet cost drops to O(models) shared bytes + O(workers) cheap zeroed
//! buffers, which is what the serving registry
//! ([`crate::serving::ModelRegistry`]) builds hot-swappable versions on.
//!
//! [`MicroInterpreter`]: super::MicroInterpreter

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::views::{TensorView, TensorViewMut};
use super::{next_owner_token, ArenaUsageDetail, Options, PlannerChoice};
use crate::arena::{ArenaUsage, DEFAULT_ALIGN};
use crate::error::{Error, Result};
use crate::ops::{DataLoc, Kernel, OpContext, OpData, OpResolver, PrepareContext};
use crate::planner::{
    analyze_lifetimes, BufferRequest, GreedyPlanner, LinearPlanner, MemoryPlanner, OfflinePlanner,
};
use crate::rewriter::{self, RewriteOutcome};
use crate::schema::Model;
use crate::tensor::DType;

fn align_up(x: usize, a: usize) -> usize {
    (x + a - 1) & !(a - 1)
}

/// Heap buffer with a `DEFAULT_ALIGN`-aligned base.
///
/// `OpContext`'s checked casts (`cast_i32`/`cast_f32`) verify pointer
/// alignment, and the memory plan aligns offsets only relative to the
/// base — so the base itself must be aligned, like an `Arena`'s.
/// Interior mutability follows the [`super::SharedArena`] precedent:
/// kernels write through a raw base pointer obtained from a shared
/// reference during the (externally synchronized) populate pass.
pub(crate) struct AlignedBuf {
    raw: UnsafeCell<Box<[u8]>>,
    base: usize,
    len: usize,
}

// SAFETY: writes through `base_ptr()` happen only (a) during the
// single-threaded build/populate pass, before the buffer is ever shared,
// or (b) at invoke time into an ExecState buffer reachable only through
// `&mut ExecState` — the borrow checker serializes those. All shared
// (`&`) access after build is read-only.
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    fn zeroed(len: usize) -> Self {
        let raw = vec![0u8; len + DEFAULT_ALIGN].into_boxed_slice();
        let base = raw.as_ptr().align_offset(DEFAULT_ALIGN);
        AlignedBuf { raw: UnsafeCell::new(raw), base, len }
    }

    fn base_ptr(&self) -> *mut u8 {
        // SAFETY: see the Sync impl — callers uphold the exclusivity
        // contract for writes; the pointer itself is always valid.
        unsafe { (*self.raw.get()).as_mut_ptr().add(self.base) }
    }

    /// Shared read of the buffer contents (valid while no writer runs;
    /// see the Sync impl).
    fn slice(&self) -> &[u8] {
        // SAFETY: as in base_ptr; read-only view.
        unsafe { &(*self.raw.get())[self.base..self.base + self.len] }
    }

    fn slice_mut(&mut self) -> &mut [u8] {
        let base = self.base;
        let len = self.len;
        &mut self.raw.get_mut()[base..base + len]
    }
}

/// Per-worker mutable execution state for one [`PreparedModel`]:
/// activations + scratch (the planned region), variable tensors, and
/// per-op degrade flags. Cheap to create — one zeroed allocation, no
/// prepare/populate work — so a worker can rebuild it after a panic or
/// a version swap without touching the shared model.
pub struct ExecState {
    buf: AlignedBuf,
    /// Per-op accelerated-kernel degrade flags (set on offload failure;
    /// scoped to this execution state, not the shared kernels).
    degraded: Vec<AtomicBool>,
    invocations: u64,
}

impl ExecState {
    /// Number of ops currently marked degraded in this execution state.
    pub fn degraded_ops(&self) -> usize {
        self.degraded.iter().filter(|f| f.load(Ordering::Relaxed)).count()
    }

    /// Number of completed invocations through this execution state.
    pub fn invocations(&self) -> u64 {
        self.invocations
    }
}

/// Activation/scratch layout planned for one batch size `m > 1`: every
/// activation tensor and scratch buffer holds `m` contiguous per-request
/// lanes, so sizes (and therefore planner placements) scale by `m` while
/// lifetimes are unchanged. Weights, folded biases, and backend side
/// tables are batch-agnostic and shared across all layouts.
struct BatchLayout {
    locs: Vec<DataLoc>,
    op_scratch: Vec<Vec<(usize, usize)>>,
    exec_len: usize,
}

/// The shared immutable product of prepare → plan → populate, built once
/// per model version and shared across workers behind `Arc`.
///
/// See the module docs for the split rationale. Construction mirrors
/// [`super::MicroInterpreter`]'s build exactly — same validation, same
/// planner, same populate pass — but persistent kernel buffers land in
/// a buffer owned here (shared, charged once) while the planned
/// activation/scratch/variable region becomes a per-worker
/// [`ExecState`] layout.
///
/// With [`Options::max_batch`] > 1 the build additionally lays out the
/// plan once per batch size `m ∈ 2..=max_batch` (see [`BatchLayout`]);
/// [`PreparedModel::invoke_batched`] then runs `m` requests through one
/// op-loop pass, bit-exact against `m` sequential single invokes.
pub struct PreparedModel {
    model: Arc<Model>,
    kernels: Vec<Arc<dyn Kernel>>,
    op_data: Vec<OpData>,
    /// Shared persistent kernel buffers (packed weights, folded biases),
    /// written once by the populate pass, read-only afterwards.
    persist: AlignedBuf,
    /// Bytes actually used inside `persist` (bump watermark).
    persist_used: usize,
    /// (offset, len) into `persist` of each persistent buffer, per op.
    op_persistent: Vec<Vec<(usize, usize)>>,
    /// (offset, len) into the ExecState buffer of each scratch buffer.
    op_scratch: Vec<Vec<(usize, usize)>>,
    /// Tensor locations: `Const` into model data, `Arena` into the
    /// ExecState buffer (activations at plan offsets, variables after).
    locs: Vec<DataLoc>,
    /// Required ExecState buffer length (plan region + variables) for
    /// the single-request (m = 1) layout.
    exec_len: usize,
    /// Layouts for m ∈ 2..=max_batch (index `m - 2`); empty when built
    /// with `max_batch` = 1.
    batched: Vec<BatchLayout>,
    /// Largest batch [`PreparedModel::invoke_batched`] accepts.
    max_batch: usize,
    /// Largest exec_len across all layouts (the ExecState allocation
    /// size, so one state can serve any batch up to `max_batch`).
    max_exec_len: usize,
    /// Variable tensors: (tensor index, exec offset, len, zero byte).
    variables: Vec<(usize, usize, usize, u8)>,
    detail: ArenaUsageDetail,
    /// Kernel-held bytes outside both buffers (XLA staged literals).
    external_kernel: usize,
    /// This build's unique owner token (side-table ABA guard).
    owner: u64,
}

// SAFETY: `persist` is written only during the single-threaded build
// (see AlignedBuf's Sync impl); every post-build access through a
// shared `&PreparedModel` is read-only, and kernels are `Send + Sync`
// by trait bound. Invoke-time writes go exclusively into the caller's
// `&mut ExecState` buffer.
unsafe impl Send for PreparedModel {}
// SAFETY: same argument as Send above — post-build access through a
// shared reference never mutates `persist`.
unsafe impl Sync for PreparedModel {}

impl Drop for PreparedModel {
    fn drop(&mut self) {
        // Evict backend side-table entries (the AVX-VNNI compensation
        // cache) keyed by persistent-buffer addresses inside `persist`,
        // under this build's owner token — same ABA-guarded discipline
        // as MicroInterpreter::drop.
        let base = self.persist.base_ptr() as usize;
        for bufs in &self.op_persistent {
            for &(off, len) in bufs {
                crate::ops::opt_ops::gemm::invalidate_compensation_range(
                    (base + off) as *const u8,
                    len,
                    self.owner,
                );
            }
        }
    }
}

impl std::fmt::Debug for PreparedModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PreparedModel")
            .field("model", &self.model.description())
            .field("ops", &self.kernels.len())
            .field("shared_resident_bytes", &self.shared_resident_bytes())
            .field("exec_bytes", &self.exec_len)
            .finish()
    }
}

impl PreparedModel {
    /// Build with default options.
    pub fn new(model: Arc<Model>, resolver: &OpResolver) -> Result<Self> {
        Self::build(model, resolver, Options::default())
    }

    /// Full build: validate → resolve → prepare → plan → populate.
    pub fn build(model: Arc<Model>, resolver: &OpResolver, options: Options) -> Result<Self> {
        crate::schema::validate::validate(&model)?;
        let max_batch = options.max_batch.max(1);
        if max_batch > 1 && options.planner == PlannerChoice::Offline {
            return Err(Error::PlanFailed(
                "offline plans describe the single-request layout; max_batch > 1 needs an online planner".into(),
            ));
        }
        // --- prepare-time graph rewrite ------------------------------
        // Same gating as MicroInterpreter::build: skipped on request and
        // when an offline plan (whose offsets index the original tensor
        // table) will be applied. The rewrite runs ONCE here; every
        // batched layout below plans the already-optimized graph.
        let wants_offline = options.planner == PlannerChoice::Offline
            || (options.planner == PlannerChoice::Auto && model.offline_plan().is_some());
        let model = if options.skip_rewrite || wants_offline {
            model
        } else {
            match rewriter::rewrite(&model, Some(resolver))? {
                RewriteOutcome::Unchanged => model,
                RewriteOutcome::Rewritten { model: rewritten, .. } => {
                    crate::schema::validate::validate(&rewritten)?;
                    Arc::new(rewritten)
                }
            }
        };

        let owner = next_owner_token();
        let n_tensors = model.tensors().len();
        let n_ops = model.operators().len();

        // Runtime-structure accounting mirrors MicroInterpreter: these
        // structs live on the host heap but are charged so Table-2-style
        // reports stay faithful. They are charged once per model, not
        // per worker — that is the point of the split.
        let meta_bytes = n_tensors * std::mem::size_of::<DataLoc>()
            + n_ops
                * (std::mem::size_of::<Arc<dyn Kernel>>()
                    + std::mem::size_of::<OpData>()
                    + std::mem::size_of::<Vec<(usize, usize)>>());
        let mut detail = ArenaUsageDetail { runtime_structs: meta_bytes, ..Default::default() };

        // --- resolve kernels (owning handles — the model version must
        //     outlive the resolver) ----------------------------------
        let mut kernels: Vec<Arc<dyn Kernel>> = Vec::with_capacity(n_ops);
        for op in model.operators() {
            kernels.push(resolver.find_arc(op.key())?);
        }

        // Fused-epilogue records: refuse a kernel that can't apply one
        // (same backstop as MicroInterpreter::build).
        let fused = rewriter::fused_specs(&model)?;
        for (i, f) in fused.iter().enumerate() {
            if f.is_some() && !kernels[i].supports_fused_epilogue() {
                return Err(Error::PrepareFailed {
                    op_index: i,
                    op_name: model.operators()[i].key().to_string(),
                    reason: "model attaches a fused-epilogue record but the resolved kernel \
                             cannot apply it"
                        .into(),
                });
            }
        }

        // --- tensor data locations ----------------------------------
        // Constants point into the model; variables are placed *after*
        // the planned region in the per-worker ExecState buffer (they
        // are mutable across invokes, so they cannot be shared).
        let mut locs = vec![DataLoc::Arena { off: 0, len: 0 }; n_tensors];
        let mut variable_indices = Vec::new();
        for (ti, t) in model.tensors().iter().enumerate() {
            if let Some(b) = t.buffer {
                let (off, len) = model.buffer_range(b)?;
                if len != t.num_bytes() {
                    return Err(Error::malformed(format!(
                        "tensor {ti} ('{}'): buffer is {len} bytes, expected {}",
                        t.name,
                        t.num_bytes()
                    )));
                }
                locs[ti] = DataLoc::Const { off, len };
            } else if t.is_variable {
                variable_indices.push(ti);
            }
        }
        if max_batch > 1 && !variable_indices.is_empty() {
            return Err(Error::PlanFailed(
                "models with variable tensors carry cross-invoke state per request and cannot be batched".into(),
            ));
        }

        // --- prepare phase ------------------------------------------
        let mut op_data: Vec<OpData> = (0..n_ops).map(|_| OpData::None).collect();
        let mut scratch_sizes_per_op: Vec<Vec<usize>> = Vec::with_capacity(n_ops);
        let mut persistent_sizes_per_op: Vec<Vec<usize>> = Vec::with_capacity(n_ops);
        let mut persistent_opdata = 0usize;
        let mut external_kernel = 0usize;
        for (i, op) in model.operators().iter().enumerate() {
            let mut sizes = Vec::new();
            let mut psizes = Vec::new();
            let mut ctx = PrepareContext::new(
                i,
                op,
                &model,
                &mut sizes,
                &mut psizes,
                &mut op_data[i],
                &mut persistent_opdata,
                &mut external_kernel,
            )
            .with_fused(fused[i]);
            kernels[i].prepare(&mut ctx)?;
            scratch_sizes_per_op.push(sizes);
            persistent_sizes_per_op.push(psizes);
        }
        detail.op_data = persistent_opdata;
        detail.kernel_buffers += external_kernel;

        // --- persistent buffer layout (shared, bump-allocated) -------
        let mut persist_used = 0usize;
        let mut op_persistent: Vec<Vec<(usize, usize)>> = Vec::with_capacity(n_ops);
        for sizes in &persistent_sizes_per_op {
            let mut bufs = Vec::with_capacity(sizes.len());
            for &sz in sizes {
                let off = align_up(persist_used, DEFAULT_ALIGN);
                persist_used = off + sz;
                bufs.push((off, sz));
                detail.kernel_buffers += sz;
            }
            op_persistent.push(bufs);
        }
        let persist = AlignedBuf::zeroed(persist_used);

        // --- lifetime analysis + planning ----------------------------
        // Rewrite-alias metadata (elided views) rides along inside the
        // requests; every planner places the aliased pair at one offset.
        let info = analyze_lifetimes(&model)?;
        let mut requests: Vec<BufferRequest> = info.requests.clone();
        detail.tensors_sum = requests.iter().map(|r| r.size).sum();
        let mut scratch_req_index: Vec<Vec<usize>> = Vec::with_capacity(n_ops);
        for (i, sizes) in scratch_sizes_per_op.iter().enumerate() {
            let mut idxs = Vec::with_capacity(sizes.len());
            for &sz in sizes {
                idxs.push(requests.len());
                requests.push(BufferRequest::new(sz, i, i));
            }
            scratch_req_index.push(idxs);
        }
        detail.scratch_sum = requests[info.requests.len()..].iter().map(|r| r.size).sum();

        let plan = match options.planner {
            PlannerChoice::Greedy => GreedyPlanner.plan(&requests, DEFAULT_ALIGN)?,
            PlannerChoice::Linear => LinearPlanner.plan(&requests, DEFAULT_ALIGN)?,
            PlannerChoice::Offline | PlannerChoice::Auto => match model.offline_plan() {
                Some(mut fixed) => {
                    fixed.resize(requests.len(), -1);
                    OfflinePlanner::new(fixed).plan(&requests, DEFAULT_ALIGN)?
                }
                None if options.planner == PlannerChoice::Auto => {
                    GreedyPlanner.plan(&requests, DEFAULT_ALIGN)?
                }
                None => {
                    return Err(Error::PlanFailed(
                        "offline planner requested but model carries no plan".into(),
                    ))
                }
            },
        };
        debug_assert!(crate::planner::verify_plan(&requests, &plan).is_ok());
        detail.activation_plan = plan.arena_size;

        // --- bind exec-relative offsets ------------------------------
        // Plan region at [0, arena_size), variables bump-packed after it.
        for (k, &ti) in info.tensor_indices.iter().enumerate() {
            locs[ti] =
                DataLoc::Arena { off: plan.offsets[k], len: model.tensors()[ti].num_bytes() };
        }
        let mut op_scratch: Vec<Vec<(usize, usize)>> = Vec::with_capacity(n_ops);
        for idxs in &scratch_req_index {
            op_scratch
                .push(idxs.iter().map(|&ri| (plan.offsets[ri], requests[ri].size)).collect());
        }
        let mut exec_len = align_up(plan.arena_size, DEFAULT_ALIGN);
        let mut variables = Vec::with_capacity(variable_indices.len());
        for ti in variable_indices {
            let t = &model.tensors()[ti];
            let len = t.num_bytes();
            let off = align_up(exec_len, DEFAULT_ALIGN);
            exec_len = off + len;
            locs[ti] = DataLoc::Arena { off, len };
            detail.variables += len;
            let zero = match t.dtype {
                DType::I8 => t.quant.as_ref().map(|q| q.zero_points[0] as i8).unwrap_or(0) as u8,
                _ => 0u8,
            };
            variables.push((ti, off, len, zero));
        }

        // --- batched layouts (m ∈ 2..=max_batch) ---------------------
        // Identical lifetimes, sizes scaled by m: every activation
        // tensor and scratch buffer gains m contiguous per-request
        // lanes. The offline planner was rejected above (its offsets
        // assume m = 1); an Auto model's offline plan likewise only
        // covers the m = 1 layout, so batched layouts always come from
        // an online planner.
        let mut batched = Vec::with_capacity(max_batch.saturating_sub(1));
        let mut max_exec_len = exec_len;
        for m in 2..=max_batch {
            let mut requests_m: Vec<BufferRequest> = requests.clone();
            for r in &mut requests_m {
                r.size *= m;
            }
            let plan_m = match options.planner {
                PlannerChoice::Linear => LinearPlanner.plan(&requests_m, DEFAULT_ALIGN)?,
                _ => GreedyPlanner.plan(&requests_m, DEFAULT_ALIGN)?,
            };
            debug_assert!(crate::planner::verify_plan(&requests_m, &plan_m).is_ok());
            let mut locs_m = locs.clone();
            for (k, &ti) in info.tensor_indices.iter().enumerate() {
                locs_m[ti] = DataLoc::Arena {
                    off: plan_m.offsets[k],
                    len: model.tensors()[ti].num_bytes() * m,
                };
            }
            let mut op_scratch_m: Vec<Vec<(usize, usize)>> = Vec::with_capacity(n_ops);
            for idxs in &scratch_req_index {
                op_scratch_m.push(
                    idxs.iter().map(|&ri| (plan_m.offsets[ri], requests_m[ri].size)).collect(),
                );
            }
            let exec_len_m = align_up(plan_m.arena_size, DEFAULT_ALIGN);
            max_exec_len = max_exec_len.max(exec_len_m);
            batched.push(BatchLayout {
                locs: locs_m,
                op_scratch: op_scratch_m,
                exec_len: exec_len_m,
            });
        }

        let pm = PreparedModel {
            model,
            kernels,
            op_data,
            persist,
            persist_used,
            op_persistent,
            op_scratch,
            locs,
            exec_len,
            batched,
            max_batch,
            max_exec_len,
            variables,
            detail,
            external_kernel,
            owner,
        };

        // --- populate pass: fill shared persistent buffers once ------
        // Kernels see the invoke-time layout via a throwaway zeroed exec
        // buffer (populate only reads constants and writes persistent
        // buffers, but the context must still resolve arena locations).
        // On error the already-constructed `pm` drops, which evicts any
        // side-table entries earlier ops registered.
        {
            let scratch_exec = AlignedBuf::zeroed(pm.exec_len);
            for (i, op) in pm.model.operators().iter().enumerate() {
                let ctx = OpContext::new(
                    i,
                    op,
                    pm.model.tensors(),
                    &pm.locs,
                    pm.model.data(),
                    scratch_exec.base_ptr(),
                    pm.exec_len,
                    &pm.op_scratch[i],
                    &pm.op_persistent[i],
                    &pm.op_data[i],
                    pm.owner,
                )
                .with_persistent_region(pm.persist.base_ptr(), pm.persist_used)
                .with_populate_phase();
                pm.kernels[i].populate(&ctx)?;
            }
        }

        Ok(pm)
    }

    /// Create a fresh per-worker execution state: one zeroed buffer
    /// (sized for the largest batch layout, so any state can serve any
    /// batch up to `max_batch`), variables reset to their zero
    /// representation, no degraded ops.
    pub fn exec_state(&self) -> ExecState {
        let mut buf = AlignedBuf::zeroed(self.max_exec_len);
        {
            let bytes = buf.slice_mut();
            for &(_, off, len, zero) in &self.variables {
                bytes[off..off + len].fill(zero);
            }
        }
        ExecState {
            buf,
            degraded: (0..self.kernels.len()).map(|_| AtomicBool::new(false)).collect(),
            invocations: 0,
        }
    }

    /// Reset `es`'s variable tensors to their zero representation.
    pub fn reset_variables(&self, es: &mut ExecState) {
        let bytes = es.buf.slice_mut();
        for &(_, off, len, zero) in &self.variables {
            bytes[off..off + len].fill(zero);
        }
    }

    fn graph_tensor(&self, list: &[i32], i: usize, what: &str) -> Result<usize> {
        list.get(i)
            .map(|&t| t as usize)
            .ok_or_else(|| Error::InvalidTensor(format!("{what} {i} out of range")))
    }

    /// The layout (tensor locations, scratch table, exec length) planned
    /// for batch size `m`.
    fn layout(&self, m: usize) -> Result<(&[DataLoc], &[Vec<(usize, usize)>], usize)> {
        match m {
            0 => Err(Error::InvalidTensor("batch size must be at least 1".into())),
            1 => Ok((&self.locs, &self.op_scratch, self.exec_len)),
            _ => {
                let l = self.batched.get(m - 2).ok_or_else(|| {
                    Error::InvalidTensor(format!(
                        "batch {m} exceeds max_batch {} this model was built with",
                        self.max_batch
                    ))
                })?;
                Ok((&l.locs, &l.op_scratch, l.exec_len))
            }
        }
    }

    /// Mutable view of graph input `i` inside `es` (populate before
    /// [`PreparedModel::invoke`]).
    pub fn input_mut<'s>(&'s self, es: &'s mut ExecState, i: usize) -> Result<TensorViewMut<'s>> {
        self.input_mut_batched(es, i, 1)
    }

    /// Mutable view of graph input `i` laid out for a batch of `m`
    /// requests: `m` contiguous lanes, lane `b` at element range
    /// `[b·n, (b+1)·n)` where `n` is the tensor's single-request element
    /// count. Populate all lanes before [`PreparedModel::invoke_batched`].
    pub fn input_mut_batched<'s>(
        &'s self,
        es: &'s mut ExecState,
        i: usize,
        m: usize,
    ) -> Result<TensorViewMut<'s>> {
        let (locs, _, _) = self.layout(m)?;
        let ti = self.graph_tensor(self.model.inputs(), i, "input")?;
        let meta = &self.model.tensors()[ti];
        match locs[ti] {
            DataLoc::Const { .. } => Err(Error::InvalidTensor("input is constant".into())),
            DataLoc::Arena { off, len } => {
                let bytes = &mut es.buf.slice_mut()[off..off + len];
                Ok(TensorViewMut { meta, bytes })
            }
        }
    }

    /// Read-only view of graph output `i` inside `es`.
    pub fn output<'s>(&'s self, es: &'s ExecState, i: usize) -> Result<TensorView<'s>> {
        self.output_batched(es, i, 1)
    }

    /// Read-only view of graph output `i` for a batch of `m` requests
    /// (lane layout as in [`PreparedModel::input_mut_batched`]). Valid
    /// after an [`PreparedModel::invoke_batched`] of the same `m`.
    pub fn output_batched<'s>(
        &'s self,
        es: &'s ExecState,
        i: usize,
        m: usize,
    ) -> Result<TensorView<'s>> {
        let (locs, _, _) = self.layout(m)?;
        let ti = self.graph_tensor(self.model.outputs(), i, "output")?;
        let meta = &self.model.tensors()[ti];
        let bytes = match locs[ti] {
            DataLoc::Const { off, len } => &self.model.data()[off..off + len],
            DataLoc::Arena { off, len } => &es.buf.slice()[off..off + len],
        };
        Ok(TensorView { meta, bytes })
    }

    /// Run one inference through `es`. Shared state is read-only; all
    /// writes land in `es`'s buffer, so any number of threads may invoke
    /// concurrently through the same `Arc<PreparedModel>` as long as
    /// each owns its `ExecState` (§4.6).
    pub fn invoke(&self, es: &mut ExecState) -> Result<()> {
        self.invoke_inner(es, 1)
    }

    /// Run `m` requests through one pass over the op list. Inputs must
    /// be populated for all `m` lanes via
    /// [`PreparedModel::input_mut_batched`]; outputs scatter from
    /// [`PreparedModel::output_batched`]. Bit-exact against `m`
    /// sequential [`PreparedModel::invoke`] calls: kernels visit the
    /// per-request lanes in order with unchanged arithmetic, only the
    /// per-weight-load amortization changes. `m` must be within the
    /// `max_batch` this model was built with.
    pub fn invoke_batched(&self, es: &mut ExecState, m: usize) -> Result<()> {
        self.invoke_inner(es, m)
    }

    fn invoke_inner(&self, es: &mut ExecState, m: usize) -> Result<()> {
        let (locs, op_scratch, exec_len) = self.layout(m)?;
        // Same deterministic fault points as MicroInterpreter::invoke,
        // so the serving supervision tests drive both paths identically.
        if let Some(e) = crate::faults::arena_exhaustion_point() {
            return Err(e);
        }
        let base = es.buf.base_ptr();
        for (i, op) in self.model.operators().iter().enumerate() {
            crate::faults::kernel_panic_point(op.key());
            let ctx = OpContext::new(
                i,
                op,
                self.model.tensors(),
                locs,
                self.model.data(),
                base,
                exec_len,
                &op_scratch[i],
                &self.op_persistent[i],
                &self.op_data[i],
                self.owner,
            )
            .with_persistent_region(self.persist.base_ptr(), self.persist_used)
            .with_degrade_flag(&es.degraded[i])
            .with_batch(m);
            self.kernels[i].invoke(&ctx)?;
        }
        es.invocations += 1;
        Ok(())
    }

    // --- introspection ------------------------------------------------

    /// Bytes resident **once per model version** regardless of worker
    /// count: shared persistent kernel buffers (packed weights, folded
    /// biases, side tables) plus off-arena kernel bytes (XLA staged
    /// literals / executable I/O). The O(models) term of fleet memory.
    pub fn shared_resident_bytes(&self) -> usize {
        self.persist_used + self.external_kernel
    }

    /// Bytes each [`ExecState`] allocates (activations + scratch +
    /// variables, sized for the largest batch layout). The O(workers)
    /// term of fleet memory.
    pub fn exec_bytes(&self) -> usize {
        self.max_exec_len
    }

    /// Table-2-style usage, counting shared bytes once and one worker's
    /// exec buffer as the non-persistent region.
    pub fn arena_usage(&self) -> ArenaUsage {
        let persistent = self.detail.runtime_structs
            + self.detail.op_data
            + self.persist_used
            + self.external_kernel;
        ArenaUsage {
            persistent,
            kernel_buffers: self.persist_used + self.external_kernel,
            nonpersistent: self.max_exec_len,
            total: persistent + self.max_exec_len,
            capacity: persistent + self.max_exec_len,
        }
    }

    /// Per-category breakdown (the RecordingMicroAllocator view).
    pub fn arena_usage_detail(&self) -> ArenaUsageDetail {
        self.detail
    }

    /// Number of operations in the execution list.
    pub fn op_count(&self) -> usize {
        self.kernels.len()
    }

    /// Largest batch [`PreparedModel::invoke_batched`] accepts (the
    /// [`Options::max_batch`] this model was built with; 1 by default).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// The loaded model.
    pub fn model(&self) -> &Model {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::writer::fully_connected_options;
    use crate::schema::{BuiltinOp, ModelBuilder};
    use crate::tensor::QuantParams;

    fn tiny_fc_model() -> Model {
        let mut b = ModelBuilder::new("prepared-tiny");
        let q = QuantParams::per_tensor(1.0, 0);
        let t_in = b.add_quant_tensor("in", DType::I8, &[1, 4], None, q.clone());
        let wbuf = b.add_buffer(&[1u8; 8]);
        let t_w = b.add_quant_tensor("w", DType::I8, &[2, 4], Some(wbuf), q.clone());
        let t_out = b.add_quant_tensor("out", DType::I8, &[1, 2], None, q);
        b.add_op(
            BuiltinOp::FullyConnected,
            &[t_in, t_w, -1],
            &[t_out],
            fully_connected_options(Default::default()),
        );
        b.set_io(&[t_in], &[t_out]);
        Model::from_bytes(&b.finish()).unwrap()
    }

    #[test]
    fn prepared_model_matches_interpreter_output() {
        let model = Arc::new(tiny_fc_model());
        let resolver = OpResolver::with_reference_ops();

        // Baseline: classic per-worker interpreter.
        let mut arena = crate::arena::Arena::new(64 * 1024);
        let mut interp =
            super::super::MicroInterpreter::new(&model, &resolver, &mut arena).unwrap();
        interp.input_mut(0).unwrap().copy_from_i8(&[1, 2, 3, 4]).unwrap();
        interp.invoke().unwrap();
        let expect = interp.output(0).unwrap().as_i8().unwrap().to_vec();

        let pm = PreparedModel::new(Arc::clone(&model), &resolver).unwrap();
        let mut es = pm.exec_state();
        pm.input_mut(&mut es, 0).unwrap().copy_from_i8(&[1, 2, 3, 4]).unwrap();
        pm.invoke(&mut es).unwrap();
        assert_eq!(pm.output(&es, 0).unwrap().as_i8().unwrap(), &expect[..]);
        assert_eq!(es.invocations(), 1);
    }

    #[test]
    fn exec_states_are_independent() {
        let resolver = OpResolver::with_reference_ops();
        let pm = PreparedModel::new(Arc::new(tiny_fc_model()), &resolver).unwrap();

        let mut a = pm.exec_state();
        let mut b = pm.exec_state();
        pm.input_mut(&mut a, 0).unwrap().copy_from_i8(&[1, 1, 1, 1]).unwrap();
        pm.input_mut(&mut b, 0).unwrap().copy_from_i8(&[2, 2, 2, 2]).unwrap();
        pm.invoke(&mut a).unwrap();
        pm.invoke(&mut b).unwrap();
        assert_eq!(pm.output(&a, 0).unwrap().as_i8().unwrap(), &[4, 4]);
        assert_eq!(pm.output(&b, 0).unwrap().as_i8().unwrap(), &[8, 8]);
    }

    #[test]
    fn shared_bytes_do_not_scale_with_exec_states() {
        let resolver = OpResolver::with_optimized_ops();
        let pm = PreparedModel::new(Arc::new(tiny_fc_model()), &resolver).unwrap();
        let before = pm.shared_resident_bytes();
        let _states: Vec<ExecState> = (0..8).map(|_| pm.exec_state()).collect();
        assert_eq!(pm.shared_resident_bytes(), before);
        assert!(pm.exec_bytes() > 0);
    }

    #[test]
    fn batched_invoke_matches_sequential_invokes() {
        let resolver = OpResolver::with_optimized_ops();
        let pm = PreparedModel::build(
            Arc::new(tiny_fc_model()),
            &resolver,
            Options { max_batch: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(pm.max_batch(), 4);
        let lanes: [[i8; 4]; 3] = [[1, 2, 3, 4], [-4, 0, 7, 1], [5, 5, 5, 5]];

        // Sequential baseline through the same model.
        let mut es = pm.exec_state();
        let mut want = Vec::new();
        for lane in &lanes {
            pm.input_mut(&mut es, 0).unwrap().copy_from_i8(lane).unwrap();
            pm.invoke(&mut es).unwrap();
            want.extend_from_slice(pm.output(&es, 0).unwrap().as_i8().unwrap());
        }

        // One batched invoke over the same three lanes.
        let mut es_b = pm.exec_state();
        let flat: Vec<i8> = lanes.iter().flatten().copied().collect();
        pm.input_mut_batched(&mut es_b, 0, 3).unwrap().copy_from_i8(&flat).unwrap();
        pm.invoke_batched(&mut es_b, 3).unwrap();
        assert_eq!(pm.output_batched(&es_b, 0, 3).unwrap().as_i8().unwrap(), &want[..]);
    }

    #[test]
    fn batch_beyond_max_is_rejected() {
        let resolver = OpResolver::with_reference_ops();
        let pm = PreparedModel::build(
            Arc::new(tiny_fc_model()),
            &resolver,
            Options { max_batch: 2, ..Default::default() },
        )
        .unwrap();
        let mut es = pm.exec_state();
        assert!(pm.invoke_batched(&mut es, 3).is_err());
        assert!(pm.invoke_batched(&mut es, 0).is_err());
        // m within bounds still works.
        pm.input_mut_batched(&mut es, 0, 2).unwrap().copy_from_i8(&[1; 8]).unwrap();
        pm.invoke_batched(&mut es, 2).unwrap();
    }

    #[test]
    fn offline_planner_rejects_batching() {
        let resolver = OpResolver::with_reference_ops();
        let err = PreparedModel::build(
            Arc::new(tiny_fc_model()),
            &resolver,
            Options { planner: PlannerChoice::Offline, max_batch: 2, ..Default::default() },
        );
        assert!(err.is_err());
    }
}
