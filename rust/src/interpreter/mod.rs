//! The TF Micro interpreter (§4.1, §4.2).
//!
//! Life cycle, exactly as the paper lays out:
//!
//! 1. the application builds an [`crate::ops::OpResolver`] (which controls
//!    which kernels link into the binary),
//! 2. supplies a contiguous memory **arena**,
//! 3. constructs a `MicroInterpreter`, which first validates the model and
//!    runs the prepare-time **graph rewriter** ([`crate::rewriter`]) —
//!    folding pads into SAME convolutions, eliding no-op views, and fusing
//!    requant epilogues, all provably bit-exact — unless
//!    [`Options::skip_rewrite`] is set or an offline plan is in play
//!    (offline offsets index the original tensor table), and then performs
//!    *all* allocation up
//!    front in the **prepare → plan → populate** sequence: kernel
//!    `prepare` calls communicate scratch and persistent-buffer needs,
//!    lifetimes are analyzed, the memory planner places every
//!    intermediate tensor, kernel persistent buffers are carved from the
//!    arena tail, the arena is sealed — no allocation can happen
//!    afterwards — and finally each kernel's `populate` runs once to fill
//!    its persistent buffers (repacked weights, folded biases, backend
//!    side tables) and to finish vendor/accelerated-kernel staging (the
//!    XLA/PJRT kernel compiles its executable, uploads weight/bias
//!    literals, and runs one warm-up execution here), so that neither
//!    model-constant work nor compilation ever executes on the inference
//!    path,
//! 4. per inference: populate input views, call [`MicroInterpreter::invoke`]
//!    (a simple blocking loop over the topologically sorted op list), read
//!    output views.
//!
//! The interpreter keeps **no state outside the arena + its own struct**,
//! which is what makes multiple interpreters on multiple cores safe
//! (§4.6) and shared-arena multitenancy possible (§4.5, [`SharedArena`]).

pub mod prepared;
mod shared;
mod views;

pub use prepared::{ExecState, PreparedModel};
pub use shared::SharedArena;
pub use views::{TensorView, TensorViewMut};

use crate::arena::{Arena, ArenaUsage, TwoStackAllocator, DEFAULT_ALIGN};
use crate::error::{Error, Result};
use crate::ops::{DataLoc, Kernel, OpContext, OpData, OpResolver, PrepareContext};
use crate::planner::{
    analyze_lifetimes, BufferRequest, GreedyPlanner, LinearPlanner, MemoryPlanner, OfflinePlanner,
};
use crate::rewriter::{self, RewriteOutcome};
use crate::schema::Model;
use crate::tensor::DType;

/// The interpreter's model handle: borrowed when the graph rewriter left
/// the caller's model untouched, owned when it produced a rewritten copy.
enum ModelRef<'m> {
    Borrowed(&'m Model),
    Owned(Box<Model>),
}

impl<'m> std::ops::Deref for ModelRef<'m> {
    type Target = Model;
    fn deref(&self) -> &Model {
        match self {
            ModelRef::Borrowed(m) => m,
            ModelRef::Owned(m) => m,
        }
    }
}

/// Which memory planner the interpreter should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlannerChoice {
    /// First-fit-decreasing bin packing (the production default, §4.4.2).
    #[default]
    Greedy,
    /// No-reuse baseline (Figure 4a; ablation only).
    Linear,
    /// Use the offline plan carried in model metadata; error if absent.
    Offline,
    /// Offline plan if the model carries one, else greedy (TF Micro's
    /// actual behaviour).
    Auto,
}

/// Interpreter construction options.
#[derive(Debug, Clone, Copy)]
pub struct Options {
    /// Memory-planning strategy.
    pub planner: PlannerChoice,
    /// Skip the prepare-time graph rewriter ([`crate::rewriter`]) and run
    /// the model exactly as loaded. The default (`false`) rewrites
    /// eligible graphs before planning; set this for ablation (`tfmicro
    /// mem`/benches report the delta) or to debug a suspected rewrite.
    /// The rewriter is also skipped automatically whenever an offline
    /// plan is used, since its offsets index the original tensor table.
    pub skip_rewrite: bool,
    /// Largest batch a [`PreparedModel`] built with these options can
    /// serve through [`PreparedModel::invoke_batched`]. The activation /
    /// scratch plan is laid out once per batch size `m ∈ 1..=max_batch`
    /// (weights, folded biases, and backend side tables are batch-agnostic
    /// and shared), and `ExecState` buffers are sized for the largest
    /// layout. 1 (the default) keeps the classic single-request layout;
    /// `MicroInterpreter` ignores this field and always runs at batch 1.
    pub max_batch: usize,
}

impl Default for Options {
    fn default() -> Self {
        Options { planner: PlannerChoice::default(), skip_rewrite: false, max_batch: 1 }
    }
}

/// Observer of per-op invoke events (implemented by the profiler; the
/// no-op impl on `()` keeps the fast path free of indirection costs when
/// unused).
pub trait InvokeObserver {
    /// An op is about to run.
    fn begin_op(&mut self, op_index: usize, key: &str);
    /// The op finished.
    fn end_op(&mut self, op_index: usize);
}

impl InvokeObserver for () {
    #[inline]
    fn begin_op(&mut self, _: usize, _: &str) {}
    #[inline]
    fn end_op(&mut self, _: usize) {}
}

enum Backing<'a> {
    Exclusive { base: *mut u8, len: usize, alloc: TwoStackAllocator },
    Shared { arena: &'a SharedArena, persistent: usize, head_size: usize, kernel_buffers: usize },
}

// SAFETY: the Exclusive variant's pointer derives from a `&'a mut [u8]`
// held exclusively for 'a; Shared is !Sync by construction (SharedArena
// contains Cells) and the interpreter is then not Send either via the
// &SharedArena field.
unsafe impl<'a> Send for Backing<'a> {}

impl<'a> Backing<'a> {
    fn alloc_tail(&mut self, size: usize, align: usize) -> Result<usize> {
        match self {
            Backing::Exclusive { alloc, .. } => alloc.alloc_tail(size, align),
            Backing::Shared { arena, persistent, .. } => {
                let off = arena.alloc_tail(size, align)?;
                *persistent = arena.persistent_used();
                Ok(off)
            }
        }
    }

    /// Tail allocation tagged as a kernel persistent buffer (packed
    /// weights, folded biases) for the ArenaUsage breakdown.
    fn alloc_tail_kernel(&mut self, size: usize, align: usize) -> Result<usize> {
        match self {
            Backing::Exclusive { alloc, .. } => alloc.alloc_tail_kernel(size, align),
            Backing::Shared { arena, persistent, kernel_buffers, .. } => {
                let before = arena.persistent_used();
                let off = arena.alloc_tail(size, align)?;
                *persistent = arena.persistent_used();
                *kernel_buffers += arena.persistent_used() - before;
                Ok(off)
            }
        }
    }

    fn reserve_head(&mut self, size: usize) -> Result<usize> {
        match self {
            Backing::Exclusive { alloc, .. } => alloc.reserve_head(size, DEFAULT_ALIGN),
            Backing::Shared { arena, head_size, .. } => {
                let off = arena.reserve_head(size)?;
                *head_size = size;
                Ok(off)
            }
        }
    }

    fn seal(&mut self) {
        if let Backing::Exclusive { alloc, .. } = self {
            alloc.seal();
        }
    }

    fn base_ptr(&self) -> *mut u8 {
        match self {
            Backing::Exclusive { base, .. } => *base,
            Backing::Shared { arena, .. } => arena.base_ptr(),
        }
    }

    fn len(&self) -> usize {
        match self {
            Backing::Exclusive { len, .. } => *len,
            Backing::Shared { arena, .. } => arena.capacity(),
        }
    }
}

/// Per-category arena accounting — the `RecordingMicroAllocator` analog
/// behind the paper's Table 2 analysis (§5.3): where exactly the
/// persistent and non-persistent bytes go.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaUsageDetail {
    /// Interpreter-lifetime runtime structures (tensor locs, kernel
    /// bindings, scratch tables) — tail.
    pub runtime_structs: usize,
    /// Prepared per-op kernel state (requant tables etc.) — tail.
    pub op_data: usize,
    /// Kernel persistent buffers (packed weights, folded biases) filled
    /// during the populate pass — tail.
    pub kernel_buffers: usize,
    /// Variable tensors (persistent state) — tail.
    pub variables: usize,
    /// The planned non-persistent region (activations + scratch) — head.
    pub activation_plan: usize,
    /// Sum of activation tensor sizes inside the plan (pre-compaction).
    pub tensors_sum: usize,
    /// Sum of kernel scratch sizes inside the plan.
    pub scratch_sum: usize,
}

impl ArenaUsageDetail {
    /// Multi-line report (used by `tfmicro mem --detail`).
    pub fn report(&self) -> String {
        format!(
            "persistent:\n  runtime structs {:>8} B\n  op data         {:>8} B\n  kernel buffers  {:>8} B\n  variables       {:>8} B\nnon-persistent (planned) {} B\n  activations sum {:>8} B (compaction saves {} B)\n  scratch sum     {:>8} B",
            self.runtime_structs,
            self.op_data,
            self.kernel_buffers,
            self.variables,
            self.activation_plan,
            self.tensors_sum,
            (self.tensors_sum + self.scratch_sum).saturating_sub(self.activation_plan),
            self.scratch_sum,
        )
    }
}

/// Hands out one unique owner token per interpreter build (never 0 =
/// `gemm::NO_OWNER`, never reused): the tag that scopes backend
/// side-table entries to the interpreter whose populate pass wrote them.
static OWNER_TOKENS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

pub(crate) fn next_owner_token() -> u64 {
    OWNER_TOKENS.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1
}

/// The interpreter. See module docs for the life cycle.
pub struct MicroInterpreter<'m, 'a> {
    model: ModelRef<'m>,
    backing: Backing<'a>,
    locs: Vec<DataLoc>,
    kernels: Vec<&'m dyn Kernel>,
    op_data: Vec<OpData>,
    op_scratch: Vec<Vec<(usize, usize)>>,
    /// (offset, len) of each persistent kernel buffer, per op.
    op_persistent: Vec<Vec<(usize, usize)>>,
    usage: ArenaUsage,
    detail: ArenaUsageDetail,
    /// Kernel-held bytes outside the arena (XLA/vendor staged buffers),
    /// folded into the `ArenaUsage` persistent/kernel_buffers totals.
    external_kernel: usize,
    /// This build's unique owner token (see [`next_owner_token`]).
    owner: u64,
    invocations: u64,
}

impl<'m, 'a> std::fmt::Debug for MicroInterpreter<'m, 'a> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MicroInterpreter")
            .field("model", &self.model.description())
            .field("ops", &self.kernels.len())
            .field("usage", &self.usage)
            .field("invocations", &self.invocations)
            .finish()
    }
}

impl<'m, 'a> Drop for MicroInterpreter<'m, 'a> {
    fn drop(&mut self) {
        // The populate pass may have registered backend side-table
        // entries (the AVX-VNNI compensation cache) keyed by persistent
        // packed-buffer addresses inside this arena. Arena storage is
        // routinely reused for the next interpreter build, so evict them
        // before the addresses can be recycled under different weights.
        // Eviction is per persistent buffer — not the whole backing range
        // — so co-tenants of a SharedArena keep their own entries, and it
        // passes this build's owner token, so a *late* drop (after a
        // newer interpreter re-registered the same recycled addresses)
        // cannot destroy the newer build's entries — the ABA guard.
        let base = self.backing.base_ptr() as usize;
        for bufs in &self.op_persistent {
            for &(off, len) in bufs {
                crate::ops::opt_ops::gemm::invalidate_compensation_range(
                    (base + off) as *const u8,
                    len,
                    self.owner,
                );
            }
        }
    }
}

impl<'m, 'a> MicroInterpreter<'m, 'a> {
    /// Construct over an exclusive arena with default options.
    pub fn new(model: &'m Model, resolver: &'m OpResolver, arena: &'a mut Arena) -> Result<Self> {
        Self::with_options(model, resolver, arena.as_mut_slice(), Options::default())
    }

    /// Construct over an exclusive byte buffer (what an MCU build uses).
    pub fn from_slice(
        model: &'m Model,
        resolver: &'m OpResolver,
        arena: &'a mut [u8],
    ) -> Result<Self> {
        Self::with_options(model, resolver, arena, Options::default())
    }

    /// Construct over an exclusive byte buffer with explicit options.
    pub fn with_options(
        model: &'m Model,
        resolver: &'m OpResolver,
        arena: &'a mut [u8],
        options: Options,
    ) -> Result<Self> {
        let backing = Backing::Exclusive {
            base: arena.as_mut_ptr(),
            len: arena.len(),
            alloc: TwoStackAllocator::new(arena.len()),
        };
        Self::build(model, resolver, backing, options)
    }

    /// Construct as a tenant of a [`SharedArena`] (§4.5).
    pub fn new_shared(
        model: &'m Model,
        resolver: &'m OpResolver,
        arena: &'a SharedArena,
    ) -> Result<Self> {
        Self::new_shared_with(model, resolver, arena, Options::default())
    }

    /// Shared-arena construction with explicit options.
    pub fn new_shared_with(
        model: &'m Model,
        resolver: &'m OpResolver,
        arena: &'a SharedArena,
        options: Options,
    ) -> Result<Self> {
        let backing = Backing::Shared { arena, persistent: 0, head_size: 0, kernel_buffers: 0 };
        Self::build(model, resolver, backing, options)
    }

    fn build(
        model: &'m Model,
        resolver: &'m OpResolver,
        mut backing: Backing<'a>,
        options: Options,
    ) -> Result<Self> {
        crate::schema::validate::validate(model)?;

        // --- prepare-time graph rewrite ---------------------------------
        // Optimize the graph before a single byte is planned. Skipped on
        // request (ablation/debugging) and whenever an offline plan will
        // be applied: its offsets index the original tensor table, and a
        // host that wanted both will have precomputed the plan against an
        // already-rewritten model.
        let wants_offline = options.planner == PlannerChoice::Offline
            || (options.planner == PlannerChoice::Auto && model.offline_plan().is_some());
        let model: ModelRef<'m> = if options.skip_rewrite || wants_offline {
            ModelRef::Borrowed(model)
        } else {
            match rewriter::rewrite(model, Some(resolver))? {
                RewriteOutcome::Unchanged => ModelRef::Borrowed(model),
                RewriteOutcome::Rewritten { model: rewritten, .. } => {
                    // The rewritten graph must satisfy every invariant the
                    // original did — a rewriter bug fails the build here,
                    // never at invoke time.
                    crate::schema::validate::validate(&rewritten)?;
                    ModelRef::Owned(Box::new(rewritten))
                }
            }
        };

        let owner = next_owner_token();
        let n_tensors = model.tensors().len();
        let n_ops = model.operators().len();

        // --- persistent runtime structures (tail) -----------------------
        // On an MCU these structs live in the arena tail; on the host they
        // live in this struct, but we charge the arena identically so the
        // Table 2 accounting is faithful.
        let meta_bytes = n_tensors * std::mem::size_of::<DataLoc>()
            + n_ops
                * (std::mem::size_of::<&dyn Kernel>()
                    + std::mem::size_of::<OpData>()
                    + std::mem::size_of::<Vec<(usize, usize)>>());
        backing.alloc_tail(meta_bytes, DEFAULT_ALIGN)?;
        let mut detail = ArenaUsageDetail { runtime_structs: meta_bytes, ..Default::default() };

        // --- resolve kernels (fails fast on unregistered ops, §4.1) -----
        let mut kernels: Vec<&'m dyn Kernel> = Vec::with_capacity(n_ops);
        for op in model.operators() {
            kernels.push(resolver.find(op.key())?);
        }

        // --- fused-epilogue records (rewrite metadata) ------------------
        // The fuse-epilogue pass only fires when the resolved kernel
        // advertises support, but a model rewritten elsewhere (or edited
        // by hand) could pair a fused record with a kernel that keeps the
        // default. Refuse to build rather than silently drop the fused
        // arithmetic.
        let fused = rewriter::fused_specs(&model)?;
        for (i, f) in fused.iter().enumerate() {
            if f.is_some() && !kernels[i].supports_fused_epilogue() {
                return Err(Error::PrepareFailed {
                    op_index: i,
                    op_name: model.operators()[i].key().to_string(),
                    reason: "model attaches a fused-epilogue record but the resolved kernel \
                             cannot apply it"
                        .into(),
                });
            }
        }

        // --- tensor data locations --------------------------------------
        let mut locs = vec![DataLoc::Arena { off: 0, len: 0 }; n_tensors];
        let mut variable_tensors = Vec::new();
        for (ti, t) in model.tensors().iter().enumerate() {
            if let Some(b) = t.buffer {
                let (off, len) = model.buffer_range(b)?;
                if len != t.num_bytes() {
                    return Err(Error::malformed(format!(
                        "tensor {ti} ('{}'): buffer is {len} bytes, expected {}",
                        t.name,
                        t.num_bytes()
                    )));
                }
                locs[ti] = DataLoc::Const { off, len };
            } else if t.is_variable {
                // Variables persist across invokes: interpreter lifetime.
                let off = backing.alloc_tail(t.num_bytes(), DEFAULT_ALIGN)?;
                locs[ti] = DataLoc::Arena { off, len: t.num_bytes() };
                detail.variables += t.num_bytes();
                variable_tensors.push(ti);
            }
        }

        // --- prepare phase (kernels request scratch + persistent buffers,
        //     store op data) --------------------------------------------
        let mut op_data: Vec<OpData> = (0..n_ops).map(|_| OpData::None).collect();
        let mut scratch_sizes_per_op: Vec<Vec<usize>> = Vec::with_capacity(n_ops);
        let mut persistent_sizes_per_op: Vec<Vec<usize>> = Vec::with_capacity(n_ops);
        let mut persistent_opdata = 0usize;
        // Kernel-held bytes living outside the arena (XLA/vendor staged
        // buffers), charged via PrepareContext::charge_kernel_external.
        let mut external_kernel = 0usize;
        for (i, op) in model.operators().iter().enumerate() {
            let mut sizes = Vec::new();
            let mut psizes = Vec::new();
            let mut ctx = PrepareContext::new(
                i,
                op,
                &model,
                &mut sizes,
                &mut psizes,
                &mut op_data[i],
                &mut persistent_opdata,
                &mut external_kernel,
            )
            .with_fused(fused[i]);
            kernels[i].prepare(&mut ctx)?;
            scratch_sizes_per_op.push(sizes);
            persistent_sizes_per_op.push(psizes);
        }
        backing.alloc_tail(persistent_opdata, DEFAULT_ALIGN)?;
        detail.op_data = persistent_opdata;
        detail.kernel_buffers += external_kernel;

        // --- kernel persistent buffers (tail, interpreter lifetime) -----
        // Allocated before planning so the head/tail crossing check sees
        // them; filled later by the populate pass.
        let mut op_persistent: Vec<Vec<(usize, usize)>> = Vec::with_capacity(n_ops);
        for sizes in &persistent_sizes_per_op {
            let mut bufs = Vec::with_capacity(sizes.len());
            for &sz in sizes {
                let off = backing.alloc_tail_kernel(sz, DEFAULT_ALIGN)?;
                bufs.push((off, sz));
                detail.kernel_buffers += sz;
            }
            op_persistent.push(bufs);
        }

        // --- lifetime analysis + planning --------------------------------
        // Rewrite-alias metadata (elided views) rides along inside the
        // requests; every planner places the aliased pair at one offset.
        let info = analyze_lifetimes(&model)?;
        let mut requests: Vec<BufferRequest> = info.requests.clone();
        detail.tensors_sum = requests.iter().map(|r| r.size).sum();
        // Scratch buffers live exactly during their op.
        let mut scratch_req_index: Vec<Vec<usize>> = Vec::with_capacity(n_ops);
        for (i, sizes) in scratch_sizes_per_op.iter().enumerate() {
            let mut idxs = Vec::with_capacity(sizes.len());
            for &sz in sizes {
                idxs.push(requests.len());
                requests.push(BufferRequest::new(sz, i, i));
            }
            scratch_req_index.push(idxs);
        }
        detail.scratch_sum = requests[info.requests.len()..].iter().map(|r| r.size).sum();

        let plan = match options.planner {
            PlannerChoice::Greedy => GreedyPlanner.plan(&requests, DEFAULT_ALIGN)?,
            PlannerChoice::Linear => LinearPlanner.plan(&requests, DEFAULT_ALIGN)?,
            PlannerChoice::Offline | PlannerChoice::Auto => {
                match model.offline_plan() {
                    Some(mut fixed) => {
                        // The model's plan covers its tensors; scratch
                        // entries float (-1).
                        fixed.resize(requests.len(), -1);
                        OfflinePlanner::new(fixed).plan(&requests, DEFAULT_ALIGN)?
                    }
                    None if options.planner == PlannerChoice::Auto => {
                        GreedyPlanner.plan(&requests, DEFAULT_ALIGN)?
                    }
                    None => {
                        return Err(Error::PlanFailed(
                            "offline planner requested but model carries no plan".into(),
                        ))
                    }
                }
            }
        };
        debug_assert!(crate::planner::verify_plan(&requests, &plan).is_ok());

        // --- reserve the non-persistent region and bind offsets ----------
        detail.activation_plan = plan.arena_size;
        let head_base = backing.reserve_head(plan.arena_size)?;
        for (k, &ti) in info.tensor_indices.iter().enumerate() {
            locs[ti] = DataLoc::Arena {
                off: head_base + plan.offsets[k],
                len: model.tensors()[ti].num_bytes(),
            };
        }
        let mut op_scratch: Vec<Vec<(usize, usize)>> = Vec::with_capacity(n_ops);
        for (i, idxs) in scratch_req_index.iter().enumerate() {
            op_scratch.push(
                idxs.iter()
                    .map(|&ri| (head_base + plan.offsets[ri], requests[ri].size))
                    .collect(),
            );
            let _ = i;
        }

        backing.seal();

        // --- populate pass: kernels fill their persistent buffers once --
        // Runs after sealing (the plan is final, offsets are stable) so
        // kernels see exactly the invoke-time memory layout. This is the
        // hoist point for model-constant work: packed weights, folded
        // biases, precomputed kernel sums.
        {
            let base = backing.base_ptr();
            let len = backing.len();
            for (i, op) in model.operators().iter().enumerate() {
                let ctx = OpContext::new(
                    i,
                    op,
                    model.tensors(),
                    &locs,
                    model.data(),
                    base,
                    len,
                    &op_scratch[i],
                    &op_persistent[i],
                    &op_data[i],
                    owner,
                )
                .with_populate_phase();
                if let Err(e) = kernels[i].populate(&ctx) {
                    // Earlier ops may already have registered backend
                    // side-table entries keyed into this arena; evict them
                    // (per persistent buffer and under this build's owner
                    // token, sparing SharedArena co-tenants and newer
                    // builds) before handing the storage back on the
                    // error path — no interpreter is constructed, so Drop
                    // won't run.
                    for bufs in &op_persistent {
                        for &(off, blen) in bufs {
                            crate::ops::opt_ops::gemm::invalidate_compensation_range(
                                (base as usize + off) as *const u8,
                                blen,
                                owner,
                            );
                        }
                    }
                    return Err(e);
                }
            }
        }

        let usage = match &backing {
            Backing::Exclusive { alloc, .. } => alloc.usage(),
            Backing::Shared { arena, persistent, head_size, kernel_buffers } => ArenaUsage {
                persistent: *persistent,
                kernel_buffers: *kernel_buffers,
                nonpersistent: *head_size,
                total: *persistent + *head_size,
                capacity: arena.capacity(),
            },
        };

        let mut interp = MicroInterpreter {
            model,
            backing,
            locs,
            kernels,
            op_data,
            op_scratch,
            op_persistent,
            usage,
            detail,
            external_kernel,
            owner,
            invocations: 0,
        };
        // Variables start at their zero representation.
        for ti in variable_tensors {
            interp.reset_tensor(ti)?;
        }
        Ok(interp)
    }

    // --- data access -----------------------------------------------------

    fn view_bytes(&self, ti: usize) -> Result<&[u8]> {
        match self.locs[ti] {
            DataLoc::Const { off, len } => Ok(&self.model.data()[off..off + len]),
            DataLoc::Arena { off, len } => {
                // SAFETY: planned range inside the arena (see OpContext docs).
                Ok(unsafe { std::slice::from_raw_parts(self.backing.base_ptr().add(off), len) })
            }
        }
    }

    fn view_bytes_mut(&mut self, ti: usize) -> Result<&mut [u8]> {
        match self.locs[ti] {
            DataLoc::Const { .. } => {
                Err(Error::InvalidTensor("cannot mutate constant tensor".into()))
            }
            DataLoc::Arena { off, len } => {
                // SAFETY: exclusive &mut self; planned range inside the arena.
                Ok(unsafe { std::slice::from_raw_parts_mut(self.backing.base_ptr().add(off), len) })
            }
        }
    }

    /// Read-only view of graph input `i`.
    pub fn input(&self, i: usize) -> Result<TensorView<'_>> {
        let ti = *self
            .model
            .inputs()
            .get(i)
            .ok_or_else(|| Error::InvalidTensor(format!("input {i} out of range")))?
            as usize;
        Ok(TensorView { meta: &self.model.tensors()[ti], bytes: self.view_bytes(ti)? })
    }

    /// Mutable view of graph input `i` (populate before `invoke`).
    pub fn input_mut(&mut self, i: usize) -> Result<TensorViewMut<'_>> {
        let ti = *self
            .model
            .inputs()
            .get(i)
            .ok_or_else(|| Error::InvalidTensor(format!("input {i} out of range")))?
            as usize;
        let meta = &self.model.tensors()[ti];
        match self.locs[ti] {
            DataLoc::Const { .. } => Err(Error::InvalidTensor("input is constant".into())),
            DataLoc::Arena { off, len } => Ok(TensorViewMut {
                meta,
                // SAFETY: as in view_bytes_mut (split borrows of self).
                bytes: unsafe {
                    std::slice::from_raw_parts_mut(self.backing.base_ptr().add(off), len)
                },
            }),
        }
    }

    /// Read-only view of graph output `i`.
    pub fn output(&self, i: usize) -> Result<TensorView<'_>> {
        let ti = *self
            .model
            .outputs()
            .get(i)
            .ok_or_else(|| Error::InvalidTensor(format!("output {i} out of range")))?
            as usize;
        Ok(TensorView { meta: &self.model.tensors()[ti], bytes: self.view_bytes(ti)? })
    }

    /// Read-only view of an arbitrary tensor (debugging / tests).
    pub fn tensor_view(&self, ti: usize) -> Result<TensorView<'_>> {
        if ti >= self.model.tensors().len() {
            return Err(Error::InvalidTensor(format!("tensor {ti} out of range")));
        }
        Ok(TensorView { meta: &self.model.tensors()[ti], bytes: self.view_bytes(ti)? })
    }

    /// Reset a variable tensor to its zero representation.
    fn reset_tensor(&mut self, ti: usize) -> Result<()> {
        let zero = match self.model.tensors()[ti].dtype {
            DType::I8 => self.model.tensors()[ti].quant.as_ref().map(|q| q.zero_points[0] as i8).unwrap_or(0) as u8,
            _ => 0u8,
        };
        self.view_bytes_mut(ti)?.fill(zero);
        Ok(())
    }

    /// Reset all variable tensors (e.g. between unrelated sequences).
    pub fn reset_variables(&mut self) -> Result<()> {
        for ti in 0..self.model.tensors().len() {
            if self.model.tensors()[ti].is_variable {
                self.reset_tensor(ti)?;
            }
        }
        Ok(())
    }

    // --- execution ---------------------------------------------------------

    /// Run one inference: a simple blocking loop over the sorted op list.
    pub fn invoke(&mut self) -> Result<()> {
        self.invoke_observed(&mut ())
    }

    /// Run one inference with per-op begin/end callbacks (profiling,
    /// §5.4's instrumentation hooks).
    pub fn invoke_observed(&mut self, obs: &mut dyn InvokeObserver) -> Result<()> {
        if let Backing::Shared { arena, .. } = &self.backing {
            arena.acquire()?;
        }
        let base = self.backing.base_ptr();
        let len = self.backing.len();
        let result = (|| -> Result<()> {
            // Deterministic fault point: an injected arena-exhaustion at
            // invoke surfaces as a clean application-level error, exactly
            // like a real §4.4.1 allocation failure would.
            if let Some(e) = crate::faults::arena_exhaustion_point() {
                return Err(e);
            }
            for (i, op) in self.model.operators().iter().enumerate() {
                obs.begin_op(i, op.key());
                // Deterministic fault point: injected kernel panic, used
                // by the serving supervision tests (no-op unless a fault
                // plan is installed; compiled out in plain release).
                crate::faults::kernel_panic_point(op.key());
                let ctx = OpContext::new(
                    i,
                    op,
                    self.model.tensors(),
                    &self.locs,
                    self.model.data(),
                    base,
                    len,
                    &self.op_scratch[i],
                    &self.op_persistent[i],
                    &self.op_data[i],
                    self.owner,
                );
                self.kernels[i].invoke(&ctx)?;
                obs.end_op(i);
            }
            Ok(())
        })();
        if let Backing::Shared { arena, .. } = &self.backing {
            arena.release();
        }
        self.invocations += 1;
        result
    }

    // --- introspection ----------------------------------------------------

    /// Arena accounting (Table 2's persistent/non-persistent/total).
    ///
    /// Includes kernel-held bytes living *outside* the arena (XLA/vendor
    /// staged buffers charged via
    /// [`crate::ops::PrepareContext::charge_kernel_external`]) in the
    /// `persistent`/`kernel_buffers`/`total` lines, so the report is the
    /// true init-time footprint rather than just the arena carve-up.
    pub fn arena_usage(&self) -> ArenaUsage {
        let mut u = match &self.backing {
            Backing::Exclusive { alloc, .. } => alloc.usage(),
            Backing::Shared { .. } => self.usage,
        };
        u.persistent += self.external_kernel;
        u.kernel_buffers += self.external_kernel;
        u.total += self.external_kernel;
        u
    }

    /// Per-category arena breakdown (the RecordingMicroAllocator view).
    pub fn arena_usage_detail(&self) -> ArenaUsageDetail {
        self.detail
    }

    /// Number of completed invocations.
    pub fn invocations(&self) -> u64 {
        self.invocations
    }

    /// Number of operations in the execution list.
    pub fn op_count(&self) -> usize {
        self.kernels.len()
    }

    /// The model being executed. When the graph rewriter fired this is
    /// the rewritten (owned) model, not the caller's original — op and
    /// tensor indices reflect the optimized graph; graph I/O shape and
    /// quantization are always preserved.
    pub fn model(&self) -> &Model {
        &self.model
    }
}
