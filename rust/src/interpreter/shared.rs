//! Shared arena for multitenancy (§4.5, Figure 5).
//!
//! "TF Micro supports memory-arena reuse by enabling the multiple model
//! interpreters to allocate memory from a single arena. We allow
//! interpreter-lifetime areas to stack on each other in the arena and
//! reuse the function-lifetime section for model evaluation. The reusable
//! (nonpersistent) part is set to the largest requirement."
//!
//! Layout over one buffer:
//!
//! ```text
//! | shared non-persistent (max over models) | free | B tail | A tail |
//! ^ head grows per-invoke                              persistent stacks
//! ```
//!
//! Interpreters over a [`SharedArena`] must not invoke concurrently (the
//! paper's precondition: models "need not run simultaneously"); a runtime
//! busy flag turns violations into an error instead of data corruption.
//! For concurrent execution use one exclusive arena per interpreter
//! (§4.6), as the serving layer does.

use crate::error::{Error, Result};
use std::cell::{Cell, UnsafeCell};

/// A memory arena shareable by several interpreters (single-threaded).
pub struct SharedArena {
    buf: UnsafeCell<Box<[u8]>>,
    /// Bytes consumed from the top by interpreter-lifetime (tail) data,
    /// cumulative across all tenant interpreters.
    tail_used: Cell<usize>,
    /// Largest non-persistent (head) requirement across tenants.
    head_high: Cell<usize>,
    /// True while some tenant is mid-invoke.
    busy: Cell<bool>,
}

impl SharedArena {
    /// Allocate a zeroed shared arena.
    pub fn new(size: usize) -> Self {
        SharedArena {
            buf: UnsafeCell::new(vec![0u8; size].into_boxed_slice()),
            tail_used: Cell::new(0),
            head_high: Cell::new(0),
            busy: Cell::new(false),
        }
    }

    /// Total capacity.
    pub fn capacity(&self) -> usize {
        // SAFETY: reading the length only.
        unsafe { (&*self.buf.get()).len() }
    }

    /// Base pointer (interpreter-internal).
    pub(crate) fn base_ptr(&self) -> *mut u8 {
        // SAFETY: pointer derivation only; access discipline is enforced
        // by the busy flag + allocation bookkeeping.
        unsafe { (*self.buf.get()).as_mut_ptr() }
    }

    /// Reserve `size` bytes of interpreter-lifetime (tail) storage.
    /// Returns the byte offset. Tails from successive tenants stack
    /// downward, as in Figure 5.
    pub(crate) fn alloc_tail(&self, size: usize, align: usize) -> Result<usize> {
        let cap = self.capacity();
        let new_used = self.tail_used.get() + size;
        let off = cap
            .checked_sub(new_used)
            .ok_or(Error::ArenaExhausted {
                requested: size,
                available: cap.saturating_sub(self.head_high.get() + self.tail_used.get()),
                capacity: cap,
                section: "shared-tail",
            })?
            & !(align - 1);
        let used = cap - off;
        if self.head_high.get() + used > cap {
            return Err(Error::ArenaExhausted {
                requested: size,
                available: cap.saturating_sub(self.head_high.get() + self.tail_used.get()),
                capacity: cap,
                section: "shared-tail",
            });
        }
        self.tail_used.set(used);
        Ok(off)
    }

    /// Reserve the shared non-persistent (head) region: grows to the max
    /// requirement over all tenants and returns offset 0.
    pub(crate) fn reserve_head(&self, size: usize) -> Result<usize> {
        let cap = self.capacity();
        if size + self.tail_used.get() > cap {
            return Err(Error::ArenaExhausted {
                requested: size,
                available: cap.saturating_sub(self.tail_used.get() + self.head_high.get()),
                capacity: cap,
                section: "shared-head",
            });
        }
        self.head_high.set(self.head_high.get().max(size));
        Ok(0)
    }

    /// Mark an invoke in flight; fails if one already is.
    pub(crate) fn acquire(&self) -> Result<()> {
        if self.busy.replace(true) {
            return Err(Error::Serving(
                "shared-arena interpreters must not run concurrently (§4.5)".into(),
            ));
        }
        Ok(())
    }

    /// Release the invoke flag.
    pub(crate) fn release(&self) {
        self.busy.set(false);
    }

    /// Total persistent bytes consumed by all tenants.
    pub fn persistent_used(&self) -> usize {
        self.tail_used.get()
    }

    /// Size of the shared non-persistent region (max over tenants).
    pub fn nonpersistent_used(&self) -> usize {
        self.head_high.get()
    }

    /// Peak total = stacked tails + shared head (the Figure 5 number).
    pub fn total_used(&self) -> usize {
        self.tail_used.get() + self.head_high.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tails_stack_heads_share() {
        let a = SharedArena::new(1000);
        let t1 = a.alloc_tail(100, 16).unwrap();
        let t2 = a.alloc_tail(50, 16).unwrap();
        assert!(t2 < t1, "second tenant's tail sits below the first");
        a.reserve_head(300).unwrap();
        a.reserve_head(200).unwrap(); // smaller tenant: no growth
        assert_eq!(a.nonpersistent_used(), 300);
        a.reserve_head(400).unwrap(); // bigger tenant: grows to max
        assert_eq!(a.nonpersistent_used(), 400);
        assert!(a.persistent_used() >= 150);
        assert!(a.total_used() <= 1000);
    }

    #[test]
    fn exhaustion_detected() {
        let a = SharedArena::new(256);
        a.alloc_tail(200, 16).unwrap();
        assert!(a.reserve_head(100).is_err());
        assert!(a.alloc_tail(100, 16).is_err());
    }

    #[test]
    fn busy_flag_blocks_concurrent_invoke() {
        let a = SharedArena::new(64);
        a.acquire().unwrap();
        assert!(a.acquire().is_err());
        a.release();
        assert!(a.acquire().is_ok());
    }

    /// The busy-flag violation surfaces through the *interpreter's*
    /// invoke as a typed error — a co-tenant mid-invoke turns a would-be
    /// data race into `Error::Serving`, and the tenant recovers cleanly
    /// once the flag is released (no poisoned state).
    #[test]
    fn interpreter_invoke_surfaces_busy_flag_violation() {
        use crate::schema::writer::fully_connected_options;
        use crate::schema::{BuiltinOp, Model, ModelBuilder};
        use crate::tensor::{DType, QuantParams};

        let mut b = ModelBuilder::new("shared-busy");
        let q = QuantParams::per_tensor(1.0, 0);
        let t_in = b.add_quant_tensor("in", DType::I8, &[1, 4], None, q.clone());
        let wbuf = b.add_buffer(&[1u8; 8]);
        let t_w = b.add_quant_tensor("w", DType::I8, &[2, 4], Some(wbuf), q.clone());
        let t_out = b.add_quant_tensor("out", DType::I8, &[1, 2], None, q);
        b.add_op(
            BuiltinOp::FullyConnected,
            &[t_in, t_w, -1],
            &[t_out],
            fully_connected_options(Default::default()),
        );
        b.set_io(&[t_in], &[t_out]);
        let model = Model::from_bytes(&b.finish()).unwrap();

        let resolver = crate::ops::OpResolver::with_reference_ops();
        let arena = SharedArena::new(64 * 1024);
        let mut interp =
            crate::interpreter::MicroInterpreter::new_shared(&model, &resolver, &arena).unwrap();
        interp.input_mut(0).unwrap().copy_from_i8(&[1, 2, 3, 4]).unwrap();

        // Simulate a co-tenant that is mid-invoke.
        arena.acquire().unwrap();
        let err = interp.invoke().unwrap_err();
        assert!(matches!(err, Error::Serving(_)), "got {err:?}");
        assert!(err.to_string().contains("concurrently"));

        // Releasing the flag un-wedges the tenant with no residue.
        arena.release();
        interp.invoke().unwrap();
        assert_eq!(interp.output(0).unwrap().as_i8().unwrap(), &[10, 10]);
    }
}
