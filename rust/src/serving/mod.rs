//! Always-on serving layer: the end-to-end driver substrate.
//!
//! TF Micro itself stops at `invoke()` by design (§3.1: "the design should
//! exclude any other function"); the applications the paper motivates —
//! always-on keyword spotting, person detection — run a sensor loop around
//! the interpreter. This module is that loop, generalized: a bounded
//! request queue with backpressure, N worker threads each owning a
//! **private** interpreter + arena (the §4.6 threading model: all state in
//! the arena, one interpreter per task, no shared mutable state), and
//! latency/throughput accounting for the examples and benches.
//!
//! std-only (threads + mpsc): the offline registry has no tokio, and the
//! paper's no-dependency ethos makes that the right call anyway
//! (DESIGN.md §6.6).
//!
//! **Warm-up at worker startup.** Each worker's interpreter build runs
//! the complete prepare → plan → populate sequence — including any
//! vendor/XLA kernel's compile + weight upload + warm-up execution —
//! before the worker pulls its first request. The first request a worker
//! serves therefore never pays compilation; its latency
//! ([`ServingReport::cold_start_ns`]) reflects only queue wait while the
//! fleet was initializing, and a populate regression shows up there as a
//! widening gap versus the steady-state percentiles.

use crate::arena::Arena;
use crate::error::{Error, Result};
use crate::interpreter::MicroInterpreter;
use crate::ops::OpResolver;
use crate::schema::Model;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServingConfig {
    /// Worker threads (one interpreter + arena each).
    pub workers: usize,
    /// Bound of the request queue; senders block when full (backpressure).
    pub queue_depth: usize,
    /// Arena size per worker, bytes.
    pub arena_bytes: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig { workers: 2, queue_depth: 32, arena_bytes: 256 * 1024 }
    }
}

/// One inference request: raw i8 input plus an id.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-assigned id, echoed in the response.
    pub id: u64,
    /// Input tensor data (i8 models).
    pub input: Vec<i8>,
    /// Enqueue timestamp (set by `submit`).
    pub enqueued: Instant,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Output tensor data.
    pub output: Vec<i8>,
    /// Queue + execution latency.
    pub latency: Duration,
    /// Which worker served it.
    pub worker: usize,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Requests completed.
    pub completed: usize,
    /// Wall time of the whole run.
    pub wall: Duration,
    /// Throughput in requests/second.
    pub throughput_rps: f64,
    /// Latency percentiles (p50, p95, p99).
    pub latency_p50: Duration,
    /// 95th percentile latency.
    pub latency_p95: Duration,
    /// 99th percentile latency.
    pub latency_p99: Duration,
    /// Per-worker completion counts.
    pub per_worker: Vec<usize>,
    /// Per-worker first-request latency, nanoseconds (0 for workers that
    /// served nothing). This is where init-time cost shows up end to end:
    /// each worker's interpreter build runs the full populate pass —
    /// packed weights, side tables, and any XLA compile + literal upload
    /// + warm-up — **before** pulling its first request, so worker
    /// startup, not the first request, pays the compile. What remains
    /// visible here is queue wait during startup; a populate regression
    /// (work sliding back to first invoke) widens the gap between this
    /// column and the steady-state percentiles.
    pub cold_start_ns: Vec<u64>,
}

impl ServingReport {
    /// One-line summary for logs and EXPERIMENTS.md.
    pub fn summary(&self) -> String {
        format!(
            "{} req in {:.2?}  {:.1} req/s  p50 {:?}  p95 {:?}  p99 {:?}  cold-max {:?}",
            self.completed,
            self.wall,
            self.throughput_rps,
            self.latency_p50,
            self.latency_p95,
            self.latency_p99,
            Duration::from_nanos(self.cold_start_ns.iter().copied().max().unwrap_or(0)),
        )
    }
}

/// Run a closed-loop serving session: feed `requests` through `workers`
/// interpreters and collect responses. Returns when all requests are done.
///
/// Each worker builds its own interpreter over its own arena (the §4.6
/// model); the executable code (model bytes, kernels) is shared read-only.
pub fn run_closed_loop(
    model: &Model,
    resolver: &OpResolver,
    cfg: ServingConfig,
    requests: Vec<Request>,
    expected_out_len: usize,
) -> Result<ServingReport> {
    if cfg.workers == 0 {
        return Err(Error::Serving("need at least one worker".into()));
    }
    let n = requests.len();
    let (req_tx, req_rx): (SyncSender<Request>, Receiver<Request>) =
        sync_channel(cfg.queue_depth);
    let req_rx = Mutex::new(req_rx);
    let (resp_tx, resp_rx) = sync_channel::<Response>(cfg.queue_depth.max(n));
    let errors = AtomicUsize::new(0);

    let t0 = Instant::now();
    let report = std::thread::scope(|scope| -> Result<ServingReport> {
        // Workers.
        for w in 0..cfg.workers {
            let req_rx = &req_rx;
            let resp_tx = resp_tx.clone();
            let errors = &errors;
            scope.spawn(move || {
                let mut arena = Arena::new(cfg.arena_bytes);
                // Worker startup pays everything expensive: the build runs
                // the full populate pass (packed weights, XLA compile +
                // upload + warm-up), so no request ever does.
                let mut interp = match MicroInterpreter::new(model, resolver, &mut arena) {
                    Ok(i) => i,
                    Err(_) => {
                        errors.fetch_add(1, Ordering::SeqCst);
                        return;
                    }
                };
                loop {
                    // Pull one request; lock is held only for the recv.
                    let req = {
                        let rx = req_rx.lock().expect("rx poisoned");
                        rx.recv()
                    };
                    let Ok(req) = req else { break };
                    let ok = (|| -> Result<Response> {
                        interp.input_mut(0)?.copy_from_i8(&req.input)?;
                        interp.invoke()?;
                        let out = interp.output(0)?.as_i8()?.to_vec();
                        Ok(Response {
                            id: req.id,
                            output: out,
                            latency: req.enqueued.elapsed(),
                            worker: w,
                        })
                    })();
                    match ok {
                        Ok(resp) => {
                            if resp_tx.send(resp).is_err() {
                                break;
                            }
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
            });
        }
        drop(resp_tx);

        // Feeder (blocks on the bounded queue: natural backpressure).
        scope.spawn(move || {
            for mut r in requests {
                r.enqueued = Instant::now();
                if req_tx.send(r).is_err() {
                    break;
                }
            }
            // Dropping req_tx closes the queue; workers drain and exit.
        });

        // Collector.
        let mut latencies = Vec::with_capacity(n);
        let mut per_worker = vec![0usize; cfg.workers];
        let mut cold_start_ns = vec![0u64; cfg.workers];
        let mut completed = 0usize;
        for resp in resp_rx.iter() {
            if resp.output.len() != expected_out_len {
                return Err(Error::Serving(format!(
                    "response {} has {} outputs, expected {expected_out_len}",
                    resp.id,
                    resp.output.len()
                )));
            }
            if per_worker[resp.worker] == 0 {
                cold_start_ns[resp.worker] = resp.latency.as_nanos() as u64;
            }
            latencies.push(resp.latency);
            per_worker[resp.worker] += 1;
            completed += 1;
        }
        let wall = t0.elapsed();
        if errors.load(Ordering::SeqCst) > 0 {
            return Err(Error::Serving(format!(
                "{} request(s) failed",
                errors.load(Ordering::SeqCst)
            )));
        }
        latencies.sort();
        let pick = |p: f64| -> Duration {
            if latencies.is_empty() {
                Duration::ZERO
            } else {
                latencies[((latencies.len() as f64 * p) as usize).min(latencies.len() - 1)]
            }
        };
        Ok(ServingReport {
            completed,
            wall,
            throughput_rps: completed as f64 / wall.as_secs_f64().max(1e-9),
            latency_p50: pick(0.50),
            latency_p95: pick(0.95),
            latency_p99: pick(0.99),
            per_worker,
            cold_start_ns,
        })
    })?;
    Ok(report)
}

/// Build a batch of identical-shape requests from a generator closure.
pub fn make_requests(count: usize, mut gen: impl FnMut(u64) -> Vec<i8>) -> Vec<Request> {
    (0..count as u64)
        .map(|id| Request { id, input: gen(id), enqueued: Instant::now() })
        .collect()
}

#[cfg(test)]
mod tests {
    // Integration coverage lives in rust/tests/serving.rs (needs a real
    // model); unit-level sanity for the helpers here.
    use super::*;

    #[test]
    fn make_requests_assigns_ids() {
        let reqs = make_requests(4, |id| vec![id as i8; 2]);
        assert_eq!(reqs.len(), 4);
        assert_eq!(reqs[3].id, 3);
        assert_eq!(reqs[2].input, vec![2i8, 2]);
    }

    /// `cold_start_ns` surfaces per-worker first-request latency: one
    /// entry per worker, nonzero exactly for workers that served at
    /// least one request, and equal to a latency the percentile stats
    /// could have observed (it is a real response latency, not a
    /// synthetic number).
    #[test]
    fn cold_start_ns_tracks_first_request_per_worker() {
        use crate::schema::writer::fully_connected_options;
        use crate::schema::{BuiltinOp, Model, ModelBuilder};
        use crate::tensor::{DType, QuantParams};

        let mut b = ModelBuilder::new("cold-start");
        let q = QuantParams::per_tensor(1.0, 0);
        let t_in = b.add_quant_tensor("in", DType::I8, &[1, 4], None, q.clone());
        let wbuf = b.add_buffer(&[1u8; 8]);
        let t_w = b.add_quant_tensor("w", DType::I8, &[2, 4], Some(wbuf), q.clone());
        let t_out = b.add_quant_tensor("out", DType::I8, &[1, 2], None, q);
        b.add_op(
            BuiltinOp::FullyConnected,
            &[t_in, t_w, -1],
            &[t_out],
            fully_connected_options(Default::default()),
        );
        b.set_io(&[t_in], &[t_out]);
        let model = Model::from_bytes(&b.finish()).unwrap();
        let resolver = crate::ops::OpResolver::with_optimized_ops();

        let requests = make_requests(16, |id| vec![id as i8; 4]);
        let cfg = ServingConfig { workers: 2, queue_depth: 4, arena_bytes: 16 * 1024 };
        let report = run_closed_loop(&model, &resolver, cfg, requests, 2).unwrap();

        assert_eq!(report.completed, 16);
        assert_eq!(report.cold_start_ns.len(), 2, "one cold-start entry per worker");
        for (w, (&served, &cold)) in
            report.per_worker.iter().zip(&report.cold_start_ns).enumerate()
        {
            if served > 0 {
                assert!(cold > 0, "worker {w} served {served} requests but cold_start_ns = 0");
                assert!(
                    cold <= report.wall.as_nanos() as u64,
                    "worker {w} cold start exceeds the whole run's wall time"
                );
            } else {
                assert_eq!(cold, 0, "idle worker {w} must report 0");
            }
        }
        // At least one worker served something, so the summary's cold-max
        // is nonzero and renders.
        assert!(report.cold_start_ns.iter().any(|&c| c > 0));
        assert!(report.summary().contains("cold-max"));
    }

    #[test]
    fn zero_workers_rejected() {
        // Construct a trivial model to exercise the early error path.
        let mut b = crate::schema::ModelBuilder::new("t");
        let t0 = b.add_tensor("in", crate::tensor::DType::I8, &[1], None);
        b.set_io(&[t0], &[t0]);
        let m = crate::schema::Model::from_bytes(&b.finish()).unwrap();
        let r = crate::ops::OpResolver::with_reference_ops();
        let cfg = ServingConfig { workers: 0, ..Default::default() };
        assert!(run_closed_loop(&m, &r, cfg, vec![], 1).is_err());
    }
}
