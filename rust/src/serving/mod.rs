//! Always-on serving layer: the end-to-end driver substrate.
//!
//! TF Micro itself stops at `invoke()` by design (§3.1: "the design should
//! exclude any other function"); the applications the paper motivates —
//! always-on keyword spotting, person detection — run a sensor loop around
//! the interpreter. This module is that loop, generalized: a bounded
//! request queue with backpressure, N worker threads each owning a
//! **private** interpreter + arena (the §4.6 threading model: all state in
//! the arena, one interpreter per task, no shared mutable state), and
//! latency/throughput accounting for the examples and benches.
//!
//! std-only (threads + mpsc): the offline registry has no tokio, and the
//! paper's no-dependency ethos makes that the right call anyway
//! (DESIGN.md §6.6).
//!
//! **Warm-up at worker startup.** Each worker's interpreter build runs
//! the complete prepare → plan → populate sequence — including any
//! vendor/XLA kernel's compile + weight upload + warm-up execution —
//! before the worker pulls its first request. The first request a worker
//! serves therefore never pays compilation; its latency
//! ([`ServingReport::cold_start_ns`]) reflects only queue wait while the
//! fleet was initializing, and a populate regression shows up there as a
//! widening gap versus the steady-state percentiles.
//!
//! # Batched serving (request coalescing)
//!
//! With [`ServingConfig::max_batch`] > 1 the fleet serves from **one
//! shared** [`crate::interpreter::PreparedModel`] built with the same
//! `max_batch` (packed weights, folded biases, and VNNI side tables are
//! batch-agnostic, so one copy serves every batch size); each worker
//! owns only a private `ExecState`, and a worker pull becomes a small
//! state machine:
//!
//! ```text
//!  recv first request
//!        │
//!        ▼
//!    GATHER ── holds the queue lock, recv_timeout until the batch
//!        │     window expires, the batch reaches max_batch, or the
//!        │     queue closes. Every request in this queue shares the
//!        │     compatibility key (model identity + input length,
//!        │     validated at submit), so any waiting request may join.
//!        ▼
//!    EXAMINE ── a member whose deadline already expired is shed
//!        │      individually (`deadline_misses`); its batchmates are
//!        │      kept and served.
//!        ▼
//!    INVOKE ── one batched invoke over the m surviving lanes,
//!        │     bit-exact against m sequential single invokes.
//!        ▼
//!    SCATTER ── lane b becomes member b's response; latency and
//!               `late_completions` are attributed from each request's
//!               own `enqueued` timestamp, never from batch-formation
//!               time.
//! ```
//!
//! Fault semantics under coalescing: a kernel panic poisons the whole
//! batch's execution state, but it is **one** supervision event (one
//! `panics` row, one respawn-budget charge, one poisoned arena) that
//! fails each member as its own counted loss (`panic_lost` grows by the
//! batch size). A clean invoke error likewise counts each member in
//! `invoke_errors`. With `max_batch` = 1 (the default) none of this
//! machinery engages: workers run the per-worker
//! [`MicroInterpreter`] path exactly as before and `panic_lost` equals
//! `panics`.
//!
//! # Fault model
//!
//! Always-on deployments must survive bad inputs and flaky vendor kernels
//! for months, so every failure mode is *contained and counted* rather
//! than propagated:
//!
//! * **Worker supervision.** Each request's invoke runs under
//!   `catch_unwind`. A panicking kernel poisons only its own worker: the
//!   worker's arena is marked poisoned and **never reused** (interpreter
//!   and arena are dropped and rebuilt fresh), the panicked request is the
//!   only one lost, and other in-flight requests complete unaffected.
//!   Respawns draw from a fleet-wide budget
//!   ([`ServingConfig::max_respawns`]); when it exhausts — or the whole
//!   fleet dies — a circuit breaker opens and every subsequent submit is
//!   rejected fast with [`Error::CircuitOpen`] instead of blocking on a
//!   queue nobody drains.
//! * **Deadlines + load shedding.** A [`Request`] may carry an optional
//!   deadline; workers shed already-expired requests before invoke
//!   (counted as `deadline_misses`). [`Submitter::try_submit`] and
//!   [`Submitter::submit_timeout`] reject with [`Error::QueueFull`] when
//!   the queue stays full instead of blocking forever (counted as
//!   `sheds`).
//! * **Input validation at submit.** A request whose input length does
//!   not match the model's input tensor is rejected at enqueue with
//!   [`Error::InvalidInput`] — it can never panic or truncate inside a
//!   worker.
//! * **Offload degradation.** An XLA op that fails at invoke time flips a
//!   per-op degraded flag and routes through the bit-exact CPU packed
//!   kernels from then on (see `runtime::xla_kernel`); the run reports
//!   `degraded_ops` instead of failing.
//! * **No panic ever reaches a submit caller**, and `run_*` only returns
//!   `Err` for structural problems (zero workers, no worker could
//!   initialize, output-length contract violation) — per-request failures
//!   land in the [`FaultTaxonomy`] of the returned [`ServingReport`].
//!
//! # Model lifecycle (zero-downtime updates)
//!
//! Long-lived fleets cannot stop for a model update, so [`registry`]
//! layers a versioned hot-swap lifecycle over the same worker loop.
//! Every published version walks this state machine:
//!
//! ```text
//!             publish(name, model)
//!                     │
//!                 Preparing ──prepare error/panic──▶ Rejected
//!                     │
//!                  Canary ────divergence/panic────▶ Rejected
//!                     │                    (live keeps serving)
//!              (shadow invokes
//!           compared against live
//!            + golden probes pass)
//!                     │
//!                   Live ◀──────────────────────────┐
//!                     │                             │
//!          per-version respawn budget               │ rollback to
//!               exhausted by panics                 │ last-known-good
//!                     │                             │
//!                     ├──good version remains───────┘ (RolledBack)
//!                     │
//!                     └──no good version──▶ breaker opens (Retired;
//!                                           terminal, submits reject)
//! ```
//!
//! * **Preparing** runs off the hot path: the full prepare → plan →
//!   populate pass builds a shared [`crate::interpreter::PreparedModel`]
//!   while the live version keeps serving every request.
//! * **Canary** shadow-invokes the candidate on deterministic inputs and
//!   compares outputs against the live version bit-exactly (plus optional
//!   golden input/output probes). Divergence or a panic rejects the
//!   candidate; the live version never stops serving.
//! * **Live**: workers pick up the new version's `Arc` at their next
//!   queue pull — no draining, no dropped in-flight requests.
//! * **RolledBack**: a version that starts panicking *after* promotion
//!   consumes a per-version respawn budget; exhausting it demotes the
//!   version and reinstates the last-known-good one automatically.
//! * The breaker remains the terminal state only when no good version
//!   exists to roll back to.
//!
//! The deterministic fault points driving the test suite live in
//! [`crate::faults`]: `kernel_panic`, `pjrt_execute`, `arena_exhausted`,
//! `queue_stall`, plus the lifecycle points `prepare_fail`,
//! `canary_diverge`, and `version_panic`.

mod batch;
pub mod registry;

pub use registry::{
    run_registry_closed_loop, run_registry_with_feeder, CanaryConfig, LifecycleStats,
    ModelRegistry, ModelVersion,
};

use crate::arena::Arena;
use crate::error::{Error, Result};
use crate::interpreter::{MicroInterpreter, PreparedModel};
use crate::ops::OpResolver;
use crate::schema::Model;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// First pause of the bounded exponential backoff used by blocking
/// submits polling a full queue.
const BACKOFF_START: Duration = Duration::from_micros(50);
/// Backoff ceiling: long enough to stop burning a core, short enough to
/// keep worst-case extra submit latency negligible.
const BACKOFF_CAP: Duration = Duration::from_millis(2);

/// Serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServingConfig {
    /// Worker threads (one interpreter + arena each).
    pub workers: usize,
    /// Bound of the request queue; senders block when full (backpressure).
    pub queue_depth: usize,
    /// Arena size per worker, bytes.
    pub arena_bytes: usize,
    /// Fleet-wide budget of worker respawns after kernel panics. When it
    /// exhausts the circuit breaker opens and submits reject fast.
    pub max_respawns: usize,
    /// Closed-loop feeder behavior when the queue is full: `None` blocks
    /// (pure backpressure, the pre-fault-tolerance behavior), `Some(t)`
    /// sheds the request after waiting `t` for queue space.
    pub submit_timeout: Option<Duration>,
    /// Default per-request deadline, measured from submit. Applied only
    /// to requests that don't carry their own [`Request::deadline`].
    pub default_deadline: Option<Duration>,
    /// Largest batch a worker pull may coalesce. At the default of 1 the
    /// fleet runs the classic per-worker `MicroInterpreter` path and
    /// never waits on the batch window; above 1 the workers share one
    /// `PreparedModel` built for this `max_batch` and gather compatible
    /// waiting requests into single batched invokes (see the module
    /// docs' batching state machine). `arena_bytes` is ignored in that
    /// mode — the prepared plan sizes its own buffers.
    pub max_batch: usize,
    /// Latency bound on batch formation: after the first request of a
    /// batch is pulled, a worker waits at most this long for more before
    /// invoking with whatever it has. Irrelevant at `max_batch` = 1.
    pub batch_window: Duration,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            workers: 2,
            queue_depth: 32,
            arena_bytes: 256 * 1024,
            max_respawns: 4,
            submit_timeout: None,
            default_deadline: None,
            max_batch: 1,
            batch_window: Duration::from_micros(500),
        }
    }
}

/// One inference request: raw i8 input plus an id.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-assigned id, echoed in the response.
    pub id: u64,
    /// Input tensor data (i8 models).
    pub input: Vec<i8>,
    /// Enqueue timestamp (set by `submit`).
    pub enqueued: Instant,
    /// Optional deadline: a worker sheds the request without invoking if
    /// the deadline has passed by the time it is pulled from the queue.
    pub deadline: Option<Instant>,
}

impl Request {
    /// New request with no deadline.
    pub fn new(id: u64, input: Vec<i8>) -> Self {
        Request { id, input, enqueued: Instant::now(), deadline: None }
    }

    /// Attach an absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Output tensor data.
    pub output: Vec<i8>,
    /// Queue + execution latency.
    pub latency: Duration,
    /// Which worker served it.
    pub worker: usize,
}

/// Error taxonomy for a serving run: every contained failure, counted.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultTaxonomy {
    /// Kernel panics caught by worker supervision: one count per
    /// supervision *event*, however many requests the panicking invoke
    /// carried. The requests lost to those events are counted in
    /// `panic_lost`, not in `dropped` (which covers only requests still
    /// queued when the fleet dies).
    pub panics: usize,
    /// Requests lost because the invoke serving them panicked. Equal to
    /// `panics` when serving unbatched; under coalescing
    /// ([`ServingConfig::max_batch`] > 1) a single mid-batch panic adds
    /// the whole batch's membership here while `panics` grows by one —
    /// per-event supervision accounting and per-request loss accounting,
    /// side by side.
    pub panic_lost: usize,
    /// Workers respawned with a fresh interpreter + arena after a panic.
    /// In registry runs, the panic that exhausts a version's respawn
    /// budget triggers a rollback (or opens the breaker) instead of a
    /// respawn, so it increments `rollbacks`, not this row.
    pub respawns: usize,
    /// Arenas marked poisoned and abandoned (one per caught panic).
    pub poisoned_arenas: usize,
    /// Clean `Err` returns from invoke (no unwind; worker kept).
    pub invoke_errors: usize,
    /// Requests shed by a worker because their deadline had expired.
    pub deadline_misses: usize,
    /// Requests whose invoke started before but finished after their
    /// deadline. The response is still delivered (the work was already
    /// spent) — distinct from `deadline_misses`, which are shed *before*
    /// invoke.
    pub late_completions: usize,
    /// Requests shed at submit because the queue stayed full
    /// (`try_submit` / `submit_timeout`).
    pub sheds: usize,
    /// Submits rejected fast: circuit breaker open or invalid input.
    pub rejected_submits: usize,
    /// XLA ops that degraded to the CPU kernel path during the run.
    pub degraded_ops: usize,
    /// Requests accepted into the queue but never served (fleet died
    /// with work still queued, or a registry worker pulled a request
    /// after every version was retired). Requests lost mid-invoke to a
    /// panic are counted in `panic_lost`, not here — total lost accepted
    /// requests is `dropped + panic_lost`.
    pub dropped: usize,
    /// Workers that failed to build an interpreter at all.
    pub worker_init_failures: usize,
    /// Published model versions rejected during the canary phase
    /// (registry runs only).
    pub canary_rejects: usize,
    /// Automatic rollbacks to the last-known-good version after a
    /// promoted version exhausted its respawn budget (registry runs
    /// only).
    pub rollbacks: usize,
}

impl FaultTaxonomy {
    /// True when nothing went wrong at any layer.
    pub fn is_clean(&self) -> bool {
        *self == FaultTaxonomy::default()
    }

    /// Compact single-line rendering for logs.
    pub fn summary(&self) -> String {
        format!(
            "panics {} panic-lost {} respawns {} poisoned {} invoke-err {} deadline-miss {} late {} sheds {} rejected {} degraded {} dropped {} init-fail {} canary-reject {} rollbacks {}",
            self.panics,
            self.panic_lost,
            self.respawns,
            self.poisoned_arenas,
            self.invoke_errors,
            self.deadline_misses,
            self.late_completions,
            self.sheds,
            self.rejected_submits,
            self.degraded_ops,
            self.dropped,
            self.worker_init_failures,
            self.canary_rejects,
            self.rollbacks,
        )
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Requests completed.
    pub completed: usize,
    /// Wall time of the whole run.
    pub wall: Duration,
    /// Throughput in requests/second (0.0 when nothing completed).
    pub throughput_rps: f64,
    /// Latency percentiles (p50, p95, p99).
    pub latency_p50: Duration,
    /// 95th percentile latency.
    pub latency_p95: Duration,
    /// 99th percentile latency.
    pub latency_p99: Duration,
    /// Per-worker completion counts.
    pub per_worker: Vec<usize>,
    /// Per-worker first-request latency, nanoseconds (0 for workers that
    /// served nothing). This is where init-time cost shows up end to end:
    /// each worker's interpreter build runs the full populate pass —
    /// packed weights, side tables, and any XLA compile + literal upload
    /// + warm-up — **before** pulling its first request, so worker
    /// startup, not the first request, pays the compile. What remains
    /// visible here is queue wait during startup; a populate regression
    /// (work sliding back to first invoke) widens the gap between this
    /// column and the steady-state percentiles.
    pub cold_start_ns: Vec<u64>,
    /// Contained-failure counts (see [`FaultTaxonomy`]).
    pub faults: FaultTaxonomy,
    /// Whether the circuit breaker was open when the run ended.
    pub breaker_open: bool,
    /// Name of the model version live when the run ended (registry runs
    /// only; `None` for the single-model loop, or when every version was
    /// retired).
    pub active_version: Option<String>,
}

impl ServingReport {
    /// One-line summary for logs and EXPERIMENTS.md.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} req in {:.2?}  {:.1} req/s  p50 {:?}  p95 {:?}  p99 {:?}  cold-max {:?}",
            self.completed,
            self.wall,
            self.throughput_rps,
            self.latency_p50,
            self.latency_p95,
            self.latency_p99,
            Duration::from_nanos(self.cold_start_ns.iter().copied().max().unwrap_or(0)),
        );
        if !self.faults.is_clean() {
            s.push_str("  faults[");
            s.push_str(&self.faults.summary());
            s.push(']');
        }
        if self.breaker_open {
            s.push_str("  BREAKER-OPEN");
        }
        if let Some(v) = &self.active_version {
            s.push_str("  active ");
            s.push_str(v);
        }
        s
    }
}

/// Shared fleet state: breaker, budgets, and failure counters.
struct FleetShared {
    breaker_open: AtomicBool,
    respawns_used: AtomicUsize,
    panics: AtomicUsize,
    panic_lost: AtomicUsize,
    poisoned_arenas: AtomicUsize,
    invoke_errors: AtomicUsize,
    deadline_misses: AtomicUsize,
    late_completions: AtomicUsize,
    sheds: AtomicUsize,
    rejected_submits: AtomicUsize,
    worker_init_failures: AtomicUsize,
    /// Workers that completed at least one successful interpreter build.
    started: AtomicUsize,
    /// Workers whose thread is still running.
    live: AtomicUsize,
    first_init_error: Mutex<Option<String>>,
    expected_in_len: usize,
    max_respawns: usize,
    default_deadline: Option<Duration>,
}

impl FleetShared {
    fn new(cfg: &ServingConfig, expected_in_len: usize) -> Self {
        FleetShared {
            breaker_open: AtomicBool::new(false),
            respawns_used: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
            panic_lost: AtomicUsize::new(0),
            poisoned_arenas: AtomicUsize::new(0),
            invoke_errors: AtomicUsize::new(0),
            deadline_misses: AtomicUsize::new(0),
            late_completions: AtomicUsize::new(0),
            sheds: AtomicUsize::new(0),
            rejected_submits: AtomicUsize::new(0),
            worker_init_failures: AtomicUsize::new(0),
            started: AtomicUsize::new(0),
            live: AtomicUsize::new(cfg.workers),
            first_init_error: Mutex::new(None),
            expected_in_len,
            max_respawns: cfg.max_respawns,
            default_deadline: cfg.default_deadline,
        }
    }

    fn taxonomy(&self) -> FaultTaxonomy {
        FaultTaxonomy {
            panics: self.panics.load(Ordering::SeqCst),
            panic_lost: self.panic_lost.load(Ordering::SeqCst),
            respawns: self.respawns_used.load(Ordering::SeqCst),
            poisoned_arenas: self.poisoned_arenas.load(Ordering::SeqCst),
            invoke_errors: self.invoke_errors.load(Ordering::SeqCst),
            deadline_misses: self.deadline_misses.load(Ordering::SeqCst),
            late_completions: self.late_completions.load(Ordering::SeqCst),
            sheds: self.sheds.load(Ordering::SeqCst),
            rejected_submits: self.rejected_submits.load(Ordering::SeqCst),
            degraded_ops: 0, // filled from the runtime degrade counter
            dropped: 0,      // filled by the post-run queue drain
            worker_init_failures: self.worker_init_failures.load(Ordering::SeqCst),
            canary_rejects: 0, // filled by the registry runner
            rollbacks: 0,      // filled by the registry runner
        }
    }
}

/// Handle for pushing requests into a running fleet. Owned by the feeder;
/// dropping it closes the queue, letting workers drain and exit.
pub struct Submitter<'a> {
    tx: SyncSender<Request>,
    shared: &'a FleetShared,
}

impl Submitter<'_> {
    /// Breaker + input-length validation; counts the rejection and hands
    /// back a typed error so callers can branch on the reason.
    fn precheck(&self, req: &Request) -> Result<()> {
        if self.shared.breaker_open.load(Ordering::SeqCst) {
            self.shared.rejected_submits.fetch_add(1, Ordering::SeqCst);
            return Err(Error::CircuitOpen { id: req.id });
        }
        if req.input.len() != self.shared.expected_in_len {
            self.shared.rejected_submits.fetch_add(1, Ordering::SeqCst);
            return Err(Error::InvalidInput {
                id: req.id,
                expected: self.shared.expected_in_len,
                got: req.input.len(),
            });
        }
        Ok(())
    }

    /// Stamp the enqueue time and apply the config-level default deadline.
    fn finalize(&self, mut req: Request) -> Request {
        req.enqueued = Instant::now();
        if req.deadline.is_none() {
            req.deadline = self.shared.default_deadline.map(|d| req.enqueued + d);
        }
        req
    }

    /// Blocking submit with backpressure. Unlike a raw channel send it can
    /// not wedge forever: the wait is punctuated by breaker checks, so a
    /// dead fleet turns into a fast [`Error::CircuitOpen`] rejection.
    /// Polls under a bounded exponential backoff
    /// ([`BACKOFF_START`]..[`BACKOFF_CAP`]) so a long wait on a full
    /// queue parks instead of burning a core.
    pub fn submit(&self, req: Request) -> Result<()> {
        self.precheck(&req)?;
        let mut req = self.finalize(req);
        let mut backoff = BACKOFF_START;
        loop {
            if self.shared.breaker_open.load(Ordering::SeqCst) {
                self.shared.rejected_submits.fetch_add(1, Ordering::SeqCst);
                return Err(Error::CircuitOpen { id: req.id });
            }
            match self.tx.try_send(req) {
                Ok(()) => return Ok(()),
                Err(TrySendError::Full(r)) => {
                    req = r;
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(BACKOFF_CAP);
                }
                Err(TrySendError::Disconnected(r)) => {
                    self.shared.rejected_submits.fetch_add(1, Ordering::SeqCst);
                    return Err(Error::CircuitOpen { id: r.id });
                }
            }
        }
    }

    /// Non-blocking submit: sheds with [`Error::QueueFull`] when the
    /// queue is full right now.
    pub fn try_submit(&self, req: Request) -> Result<()> {
        self.precheck(&req)?;
        let req = self.finalize(req);
        match self.tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(r)) => {
                self.shared.sheds.fetch_add(1, Ordering::SeqCst);
                Err(Error::QueueFull { id: r.id })
            }
            Err(TrySendError::Disconnected(r)) => {
                self.shared.rejected_submits.fetch_add(1, Ordering::SeqCst);
                Err(Error::CircuitOpen { id: r.id })
            }
        }
    }

    /// Submit that waits at most `timeout` for queue space, then sheds
    /// with [`Error::QueueFull`].
    pub fn submit_timeout(&self, req: Request, timeout: Duration) -> Result<()> {
        self.precheck(&req)?;
        let mut req = self.finalize(req);
        let start = Instant::now();
        let mut backoff = BACKOFF_START;
        loop {
            if self.shared.breaker_open.load(Ordering::SeqCst) {
                self.shared.rejected_submits.fetch_add(1, Ordering::SeqCst);
                return Err(Error::CircuitOpen { id: req.id });
            }
            match self.tx.try_send(req) {
                Ok(()) => return Ok(()),
                Err(TrySendError::Full(r)) => {
                    let elapsed = start.elapsed();
                    if elapsed >= timeout {
                        self.shared.sheds.fetch_add(1, Ordering::SeqCst);
                        return Err(Error::QueueFull { id: r.id });
                    }
                    req = r;
                    // Bounded exponential backoff, clipped so the timeout
                    // is not overshot by a whole backoff step.
                    std::thread::sleep(backoff.min(timeout - elapsed));
                    backoff = (backoff * 2).min(BACKOFF_CAP);
                }
                Err(TrySendError::Disconnected(r)) => {
                    self.shared.rejected_submits.fetch_add(1, Ordering::SeqCst);
                    return Err(Error::CircuitOpen { id: r.id });
                }
            }
        }
    }

    /// Whether the circuit breaker is currently open (reject-fast mode).
    pub fn breaker_open(&self) -> bool {
        self.shared.breaker_open.load(Ordering::SeqCst)
    }

    /// Live snapshot of the fleet's failure counters (degraded/dropped
    /// are only known at run end and read 0 here). Lets a feeder
    /// synchronize on fault progress without racing the final report.
    pub fn counts(&self) -> FaultTaxonomy {
        self.shared.taxonomy()
    }
}

/// Streaming response accumulator shared by both serving runners
/// (single-model and registry): latencies, per-worker counts, cold-start
/// capture, and the percentile math — extracted so the edge cases are
/// unit-testable without spinning up a fleet.
pub(crate) struct Collector {
    /// Completion latencies, sorted by [`Collector::percentiles`].
    latencies: Vec<Duration>,
    pub(crate) per_worker: Vec<usize>,
    pub(crate) cold_start_ns: Vec<u64>,
    pub(crate) completed: usize,
}

impl Collector {
    pub(crate) fn new(workers: usize) -> Self {
        Collector {
            latencies: Vec::new(),
            per_worker: vec![0usize; workers],
            cold_start_ns: vec![0u64; workers],
            completed: 0,
        }
    }

    /// Record one completed response. A worker index out of range is
    /// impossible from our own fleet but bounds-guarded anyway — this is
    /// the no-panic surface.
    pub(crate) fn record(&mut self, resp: &Response) {
        if let Some(count) = self.per_worker.get_mut(resp.worker) {
            if *count == 0 {
                if let Some(slot) = self.cold_start_ns.get_mut(resp.worker) {
                    *slot = resp.latency.as_nanos() as u64;
                }
            }
            *count += 1;
        }
        self.latencies.push(resp.latency);
        self.completed += 1;
    }

    /// Sort once, then report (p50, p95, p99) by nearest rank.
    pub(crate) fn percentiles(&mut self) -> [Duration; 3] {
        self.latencies.sort();
        [self.percentile(0.50), self.percentile(0.95), self.percentile(0.99)]
    }

    /// Nearest-rank percentile over the (sorted) latencies: the smallest
    /// sample with at least `p`·N samples at or below it,
    /// `⌈N·p⌉`-th in rank. Well-defined at every edge the old truncating
    /// `(N·p) as usize` index skewed: a batch of one reports its single
    /// sample at every percentile, two samples report the *lower* as p50
    /// (truncation reported the upper), and zero completions — an
    /// all-shed batch, a run that never served — report `Duration::ZERO`
    /// without dividing by or indexing anything.
    pub(crate) fn percentile(&self, p: f64) -> Duration {
        let n = self.latencies.len();
        if n == 0 {
            return Duration::ZERO;
        }
        let idx = ((n as f64 * p).ceil() as usize).saturating_sub(1).min(n - 1);
        self.latencies.get(idx).copied().unwrap_or(Duration::ZERO)
    }
}

/// Run a closed-loop serving session: feed `requests` through `workers`
/// interpreters and collect responses. Returns when all requests are done
/// (completed, shed, or rejected — see the report's [`FaultTaxonomy`]).
///
/// Each worker builds its own interpreter over its own arena (the §4.6
/// model); the executable code (model bytes, kernels) is shared read-only.
pub fn run_closed_loop(
    model: &Model,
    resolver: &OpResolver,
    cfg: ServingConfig,
    requests: Vec<Request>,
    expected_out_len: usize,
) -> Result<ServingReport> {
    let timeout = cfg.submit_timeout;
    run_with_feeder(
        model,
        resolver,
        cfg,
        expected_out_len,
        move |sub| {
            for r in requests {
                // Rejections are typed, counted in the taxonomy, and must
                // never abort the rest of the batch.
                let _ = match timeout {
                    Some(t) => sub.submit_timeout(r, t),
                    None => sub.submit(r),
                };
            }
        },
        |_resp| {},
    )
}

/// Run a serving session driven by a caller-supplied feeder closure.
///
/// The feeder receives a [`Submitter`] and fully controls submission
/// (blocking, non-blocking, timed, with or without deadlines); the queue
/// closes when the feeder returns. `on_response` observes every completed
/// response from the collector thread, in completion order.
pub fn run_with_feeder<F>(
    model: &Model,
    resolver: &OpResolver,
    cfg: ServingConfig,
    expected_out_len: usize,
    feeder: F,
    mut on_response: impl FnMut(&Response),
) -> Result<ServingReport>
where
    F: FnOnce(&Submitter<'_>) + Send,
{
    if cfg.workers == 0 {
        return Err(Error::Serving("need at least one worker".into()));
    }
    let inputs = model.inputs();
    if inputs.is_empty() {
        return Err(Error::Serving("model has no input tensors".into()));
    }
    let expected_in_len = model.tensors()[inputs[0] as usize].num_elements();
    let shared = FleetShared::new(&cfg, expected_in_len);
    let degrades_before = crate::runtime::degrade_events();

    // Coalescing mode: one shared PreparedModel planned for every batch
    // size up to max_batch, built before the fleet spawns so a planning
    // failure is one structural error, not N worker-init failures. The
    // model bytes are re-owned (PreparedModel shares by Arc) — a one-time
    // copy at run start, never on the request path.
    let prepared: Option<Arc<PreparedModel>> = if cfg.max_batch > 1 {
        let owned = Model::from_vec(model.data().to_vec())?;
        let options =
            crate::interpreter::Options { max_batch: cfg.max_batch, ..Default::default() };
        Some(Arc::new(PreparedModel::build(Arc::new(owned), resolver, options)?))
    } else {
        None
    };

    let (req_tx, req_rx): (SyncSender<Request>, Receiver<Request>) =
        sync_channel(cfg.queue_depth);
    let req_rx = Mutex::new(req_rx);
    let (resp_tx, resp_rx) = std::sync::mpsc::channel::<Response>();

    let t0 = Instant::now();
    let mut report = std::thread::scope(|scope| -> Result<ServingReport> {
        // Workers.
        for w in 0..cfg.workers {
            let req_rx = &req_rx;
            let resp_tx = resp_tx.clone();
            let shared = &shared;
            if let Some(pm) = &prepared {
                // Coalescing worker: shared PreparedModel, private
                // ExecState, batched pulls (see the module docs'
                // batching state machine).
                let pm = Arc::clone(pm);
                scope.spawn(move || {
                    shared.started.fetch_add(1, Ordering::SeqCst);
                    let mut abnormal = false;
                    let mut es = pm.exec_state();
                    'pull: loop {
                        // GATHER: block for the first request, then hold
                        // the queue lock through the latency-bounded
                        // window collecting batchmates.
                        let gathered = {
                            let rx = req_rx.lock().unwrap_or_else(|p| p.into_inner());
                            let first = match rx.recv() {
                                Ok(r) => r,
                                Err(_) => break 'pull,
                            };
                            batch::gather(&rx, first, cfg.max_batch, cfg.batch_window)
                        };
                        // EXAMINE: a member whose deadline expired while
                        // queued (or while the window ran) is shed
                        // individually; its batchmates are served.
                        let now = Instant::now();
                        let mut kept: Vec<Request> = Vec::with_capacity(gathered.len());
                        for req in gathered {
                            if let Some(d) = req.deadline {
                                if now >= d {
                                    shared.deadline_misses.fetch_add(1, Ordering::SeqCst);
                                    continue;
                                }
                            }
                            kept.push(req);
                        }
                        if kept.is_empty() {
                            continue;
                        }
                        crate::faults::queue_stall_point();
                        let m = kept.len();
                        // INVOKE: one batched pass over the op list.
                        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || -> Result<Vec<i8>> {
                                let mut view = pm.input_mut_batched(&mut es, 0, m)?;
                                if !batch::pack_lanes(view.as_i8_mut()?, &kept) {
                                    return Err(Error::Serving(
                                        "batch member input length mismatch".into(),
                                    ));
                                }
                                pm.invoke_batched(&mut es, m)?;
                                Ok(pm.output_batched(&es, 0, m)?.as_i8()?.to_vec())
                            },
                        ));
                        match unwound {
                            Ok(Ok(output)) => {
                                // SCATTER: lane b becomes member b's
                                // response; latency and lateness come
                                // from each request's own `enqueued`,
                                // never batch-formation time.
                                let lane_n = output.len() / m;
                                for (b, req) in kept.iter().enumerate() {
                                    if let Some(d) = req.deadline {
                                        if Instant::now() >= d {
                                            shared
                                                .late_completions
                                                .fetch_add(1, Ordering::SeqCst);
                                        }
                                    }
                                    let Some(out) = batch::lane(&output, lane_n, b) else {
                                        shared.invoke_errors.fetch_add(1, Ordering::SeqCst);
                                        continue;
                                    };
                                    let resp = Response {
                                        id: req.id,
                                        output: out.to_vec(),
                                        latency: req.enqueued.elapsed(),
                                        worker: w,
                                    };
                                    if resp_tx.send(resp).is_err() {
                                        break 'pull;
                                    }
                                }
                            }
                            Ok(Err(_)) => {
                                // A clean error fails every member as its
                                // own counted loss; the worker serves on.
                                shared.invoke_errors.fetch_add(m, Ordering::SeqCst);
                            }
                            Err(_payload) => {
                                // One supervision event — one panics row,
                                // one respawn-budget charge, one poisoned
                                // state — that loses all m members.
                                shared.panics.fetch_add(1, Ordering::SeqCst);
                                shared.panic_lost.fetch_add(m, Ordering::SeqCst);
                                shared.poisoned_arenas.fetch_add(1, Ordering::SeqCst);
                                let used =
                                    shared.respawns_used.fetch_add(1, Ordering::SeqCst);
                                if used >= shared.max_respawns {
                                    shared.respawns_used.fetch_sub(1, Ordering::SeqCst);
                                    shared.breaker_open.store(true, Ordering::SeqCst);
                                    abnormal = true;
                                    break 'pull;
                                }
                                // Fresh ExecState = the respawn: the
                                // shared model is immutable at invoke, so
                                // only this worker's state was poisoned.
                                es = pm.exec_state();
                            }
                        }
                    }
                    if shared.live.fetch_sub(1, Ordering::SeqCst) == 1 && abnormal {
                        shared.breaker_open.store(true, Ordering::SeqCst);
                    }
                });
                continue;
            }
            scope.spawn(move || {
                // One iteration per interpreter lifetime: the first build,
                // then one more per respawn after a caught panic. A panic
                // poisons the current arena; leaving the iteration drops
                // interpreter and arena so the next one starts fresh.
                let mut respawned = false;
                // Whether this worker died (init failure, exhausted
                // budget) rather than exiting cleanly at queue close —
                // only abnormal exits may trip the last-worker breaker.
                let mut abnormal = false;
                'respawn: loop {
                    let mut arena = Arena::new(cfg.arena_bytes);
                    // Worker startup pays everything expensive: the build
                    // runs the full populate pass (packed weights, XLA
                    // compile + upload + warm-up), so no request ever does.
                    let mut interp = match MicroInterpreter::new(model, resolver, &mut arena) {
                        Ok(i) => i,
                        Err(e) => {
                            shared.worker_init_failures.fetch_add(1, Ordering::SeqCst);
                            let mut slot = shared
                                .first_init_error
                                .lock()
                                .unwrap_or_else(|p| p.into_inner());
                            if slot.is_none() {
                                *slot = Some(e.to_string());
                            }
                            drop(slot);
                            // A *respawn* that fails to re-init shrinks the
                            // fleet just like an uncontained panic would:
                            // charge the respawn budget so repeated
                            // panic + init-failure cycles cannot silently
                            // whittle workers away under an honest budget.
                            if respawned {
                                let used = shared.respawns_used.fetch_add(1, Ordering::SeqCst);
                                if used >= shared.max_respawns {
                                    shared.respawns_used.fetch_sub(1, Ordering::SeqCst);
                                    shared.breaker_open.store(true, Ordering::SeqCst);
                                }
                            }
                            abnormal = true;
                            break 'respawn;
                        }
                    };
                    shared.started.fetch_add(1, Ordering::SeqCst);
                    loop {
                        // Pull one request; lock is held only for the recv.
                        // A poisoned lock just means another worker died
                        // mid-recv — the receiver itself is still sound.
                        let req = {
                            let rx = req_rx.lock().unwrap_or_else(|p| p.into_inner());
                            rx.recv()
                        };
                        let Ok(req) = req else { break 'respawn };
                        // Expired requests shed before invoke (and before
                        // the stall point: a stalled worker models a slow
                        // *invoke*, not a slow deadline check).
                        if let Some(d) = req.deadline {
                            if Instant::now() >= d {
                                shared.deadline_misses.fetch_add(1, Ordering::SeqCst);
                                continue;
                            }
                        }
                        crate::faults::queue_stall_point();
                        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || -> Result<Vec<i8>> {
                                interp.input_mut(0)?.copy_from_i8(&req.input)?;
                                interp.invoke()?;
                                Ok(interp.output(0)?.as_i8()?.to_vec())
                            },
                        ));
                        match unwound {
                            Ok(Ok(output)) => {
                                // The deadline may have expired *during*
                                // invoke: the work is already spent, so the
                                // response is still delivered, but counted
                                // separately from shed-before-invoke.
                                if let Some(d) = req.deadline {
                                    if Instant::now() >= d {
                                        shared
                                            .late_completions
                                            .fetch_add(1, Ordering::SeqCst);
                                    }
                                }
                                let resp = Response {
                                    id: req.id,
                                    output,
                                    latency: req.enqueued.elapsed(),
                                    worker: w,
                                };
                                if resp_tx.send(resp).is_err() {
                                    break 'respawn;
                                }
                            }
                            Ok(Err(_)) => {
                                // Clean error return: interpreter state is
                                // consistent, the worker serves on.
                                shared.invoke_errors.fetch_add(1, Ordering::SeqCst);
                            }
                            Err(_payload) => {
                                shared.panics.fetch_add(1, Ordering::SeqCst);
                                // Unbatched: the one request being served
                                // is the one loss.
                                shared.panic_lost.fetch_add(1, Ordering::SeqCst);
                                shared.poisoned_arenas.fetch_add(1, Ordering::SeqCst);
                                let used = shared.respawns_used.fetch_add(1, Ordering::SeqCst);
                                if used >= shared.max_respawns {
                                    // Budget exhausted: undo the optimistic
                                    // claim and trip the breaker.
                                    shared.respawns_used.fetch_sub(1, Ordering::SeqCst);
                                    shared.breaker_open.store(true, Ordering::SeqCst);
                                    abnormal = true;
                                    break 'respawn;
                                }
                                respawned = true;
                                continue 'respawn;
                            }
                        }
                    }
                }
                if shared.live.fetch_sub(1, Ordering::SeqCst) == 1 && abnormal {
                    // Last worker *died* (rather than exiting at queue
                    // close): nobody will ever drain the queue, so submits
                    // must reject fast from here on.
                    shared.breaker_open.store(true, Ordering::SeqCst);
                }
            });
        }
        drop(resp_tx);

        // Feeder owns the Submitter (and with it the request sender);
        // when it returns the queue closes and workers drain and exit.
        let submitter = Submitter { tx: req_tx, shared: &shared };
        scope.spawn(move || {
            feeder(&submitter);
            drop(submitter);
        });

        // Collector.
        let mut col = Collector::new(cfg.workers);
        for resp in resp_rx.iter() {
            if resp.output.len() != expected_out_len {
                // Contract violation, not a per-request fault: open the
                // breaker so the feeder unblocks, then fail the run.
                shared.breaker_open.store(true, Ordering::SeqCst);
                return Err(Error::Serving(format!(
                    "response {} has {} outputs, expected {expected_out_len}",
                    resp.id,
                    resp.output.len()
                )));
            }
            on_response(&resp);
            col.record(&resp);
        }
        let wall = t0.elapsed();

        // All workers have exited (their response senders are gone);
        // anything still queued was accepted but never served.
        let mut dropped = 0usize;
        {
            let rx = req_rx.lock().unwrap_or_else(|p| p.into_inner());
            while rx.try_recv().is_ok() {
                dropped += 1;
            }
        }

        if shared.started.load(Ordering::SeqCst) == 0 {
            let first = shared
                .first_init_error
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .take()
                .unwrap_or_else(|| "unknown".into());
            return Err(Error::Serving(format!("no worker could initialize: {first}")));
        }

        let [p50, p95, p99] = col.percentiles();
        let mut faults = shared.taxonomy();
        faults.dropped = dropped;
        Ok(ServingReport {
            completed: col.completed,
            wall,
            // Guard the zero-completion case explicitly: an all-shed run
            // reports zeros, it does not divide by a ~zero wall.
            throughput_rps: if col.completed == 0 {
                0.0
            } else {
                col.completed as f64 / wall.as_secs_f64().max(1e-9)
            },
            latency_p50: p50,
            latency_p95: p95,
            latency_p99: p99,
            per_worker: col.per_worker,
            cold_start_ns: col.cold_start_ns,
            faults,
            breaker_open: shared.breaker_open.load(Ordering::SeqCst),
            active_version: None,
        })
    })?;
    report.faults.degraded_ops =
        (crate::runtime::degrade_events() - degrades_before) as usize;
    Ok(report)
}

/// Build a batch of identical-shape requests from a generator closure.
pub fn make_requests(count: usize, mut gen: impl FnMut(u64) -> Vec<i8>) -> Vec<Request> {
    (0..count as u64).map(|id| Request::new(id, gen(id))).collect()
}

#[cfg(test)]
mod tests {
    // Integration coverage lives in rust/tests/serving.rs and
    // rust/tests/serving_faults.rs (the latter drives the fault model);
    // unit-level sanity for the helpers here.
    use super::*;

    #[test]
    fn make_requests_assigns_ids() {
        let reqs = make_requests(4, |id| vec![id as i8; 2]);
        assert_eq!(reqs.len(), 4);
        assert_eq!(reqs[3].id, 3);
        assert_eq!(reqs[2].input, vec![2i8, 2]);
        assert!(reqs[0].deadline.is_none());
    }

    fn tiny_fc_model() -> Model {
        use crate::schema::writer::fully_connected_options;
        use crate::schema::{BuiltinOp, ModelBuilder};
        use crate::tensor::{DType, QuantParams};

        let mut b = ModelBuilder::new("serving-unit");
        let q = QuantParams::per_tensor(1.0, 0);
        let t_in = b.add_quant_tensor("in", DType::I8, &[1, 4], None, q.clone());
        let wbuf = b.add_buffer(&[1u8; 8]);
        let t_w = b.add_quant_tensor("w", DType::I8, &[2, 4], Some(wbuf), q.clone());
        let t_out = b.add_quant_tensor("out", DType::I8, &[1, 2], None, q);
        b.add_op(
            BuiltinOp::FullyConnected,
            &[t_in, t_w, -1],
            &[t_out],
            fully_connected_options(Default::default()),
        );
        b.set_io(&[t_in], &[t_out]);
        Model::from_bytes(&b.finish()).unwrap()
    }

    /// `cold_start_ns` surfaces per-worker first-request latency: one
    /// entry per worker, nonzero exactly for workers that served at
    /// least one request, and equal to a latency the percentile stats
    /// could have observed (it is a real response latency, not a
    /// synthetic number).
    #[test]
    fn cold_start_ns_tracks_first_request_per_worker() {
        let model = tiny_fc_model();
        let resolver = crate::ops::OpResolver::with_optimized_ops();

        let requests = make_requests(16, |id| vec![id as i8; 4]);
        let cfg = ServingConfig {
            workers: 2,
            queue_depth: 4,
            arena_bytes: 16 * 1024,
            ..Default::default()
        };
        let report = run_closed_loop(&model, &resolver, cfg, requests, 2).unwrap();

        assert_eq!(report.completed, 16);
        assert!(report.faults.is_clean());
        assert!(!report.breaker_open);
        assert_eq!(report.cold_start_ns.len(), 2, "one cold-start entry per worker");
        for (w, (&served, &cold)) in
            report.per_worker.iter().zip(&report.cold_start_ns).enumerate()
        {
            if served > 0 {
                assert!(cold > 0, "worker {w} served {served} requests but cold_start_ns = 0");
                assert!(
                    cold <= report.wall.as_nanos() as u64,
                    "worker {w} cold start exceeds the whole run's wall time"
                );
            } else {
                assert_eq!(cold, 0, "idle worker {w} must report 0");
            }
        }
        // At least one worker served something, so the summary's cold-max
        // is nonzero and renders.
        assert!(report.cold_start_ns.iter().any(|&c| c > 0));
        assert!(report.summary().contains("cold-max"));
    }

    /// Satellite: the percentile accumulator's edge cases, unit-tested
    /// directly — batch of one, zero completed, two-sample median — so
    /// the nearest-rank math is pinned without spinning up a fleet.
    #[test]
    fn percentile_accumulator_edge_cases() {
        let resp = |ms: u64, worker: usize| Response {
            id: ms,
            output: Vec::new(),
            latency: Duration::from_millis(ms),
            worker,
        };

        // Zero completed: every percentile is ZERO — no division, no
        // indexing, no skew.
        let mut c = Collector::new(2);
        assert_eq!(c.percentiles(), [Duration::ZERO; 3]);
        assert_eq!(c.completed, 0);

        // Batch of one: the single sample IS every percentile.
        let mut c = Collector::new(1);
        c.record(&resp(7, 0));
        assert_eq!(c.percentiles(), [Duration::from_millis(7); 3]);

        // Two samples: nearest-rank p50 is the *lower* one (the old
        // truncating index reported the upper), p95/p99 the upper.
        let mut c = Collector::new(1);
        c.record(&resp(20, 0));
        c.record(&resp(10, 0));
        assert_eq!(
            c.percentiles(),
            [
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(20)
            ]
        );

        // 100 samples 1..=100 ms land exactly on their ranks.
        let mut c = Collector::new(1);
        for ms in 1u64..=100 {
            c.record(&resp(ms, 0));
        }
        let [p50, p95, p99] = c.percentiles();
        assert_eq!(p50, Duration::from_millis(50));
        assert_eq!(p95, Duration::from_millis(95));
        assert_eq!(p99, Duration::from_millis(99));

        // An out-of-range worker id is bounds-guarded, not a panic; the
        // latency still counts toward the percentiles.
        let mut c = Collector::new(1);
        c.record(&resp(3, 9));
        assert_eq!(c.completed, 1);
        assert_eq!(c.per_worker, vec![0]);
        assert_eq!(c.percentiles(), [Duration::from_millis(3); 3]);
    }

    /// Tentpole: coalesced serving returns the same bytes per request as
    /// the classic unbatched fleet, with clean fault taxonomy.
    #[test]
    fn batched_coalescing_matches_unbatched_outputs() {
        let model = tiny_fc_model();
        let resolver = crate::ops::OpResolver::with_optimized_ops();
        let run = |max_batch: usize| {
            let mut outputs = std::collections::BTreeMap::new();
            let cfg = ServingConfig {
                workers: 2,
                queue_depth: 16,
                max_batch,
                batch_window: Duration::from_millis(5),
                ..Default::default()
            };
            let report = run_with_feeder(
                &model,
                &resolver,
                cfg,
                2,
                |sub| {
                    for id in 0..24u64 {
                        sub.submit(Request::new(id, vec![(id as i8).wrapping_sub(5); 4]))
                            .unwrap();
                    }
                },
                |resp| {
                    outputs.insert(resp.id, resp.output.clone());
                },
            )
            .unwrap();
            (report, outputs)
        };
        let (unbatched, want) = run(1);
        let (batched, got) = run(4);
        assert_eq!(unbatched.completed, 24);
        assert_eq!(batched.completed, 24);
        assert!(batched.faults.is_clean(), "{}", batched.faults.summary());
        assert_eq!(got, want, "coalesced responses must be bit-exact vs unbatched");
    }

    #[test]
    fn zero_workers_rejected() {
        // Construct a trivial model to exercise the early error path.
        let mut b = crate::schema::ModelBuilder::new("t");
        let t0 = b.add_tensor("in", crate::tensor::DType::I8, &[1], None);
        b.set_io(&[t0], &[t0]);
        let m = crate::schema::Model::from_bytes(&b.finish()).unwrap();
        let r = crate::ops::OpResolver::with_reference_ops();
        let cfg = ServingConfig { workers: 0, ..Default::default() };
        assert!(run_closed_loop(&m, &r, cfg, vec![], 1).is_err());
    }

    /// Satellite: a run that completes zero requests reports zeros — no
    /// divide-by-zero throughput, no panicking percentile math.
    #[test]
    fn zero_completed_requests_report_zeros() {
        let model = tiny_fc_model();
        let resolver = crate::ops::OpResolver::with_reference_ops();
        let cfg = ServingConfig { workers: 1, ..Default::default() };
        let report = run_closed_loop(&model, &resolver, cfg, vec![], 2).unwrap();
        assert_eq!(report.completed, 0);
        assert_eq!(report.throughput_rps, 0.0);
        assert_eq!(report.latency_p50, Duration::ZERO);
        assert_eq!(report.latency_p99, Duration::ZERO);
        assert!(report.faults.is_clean());
        assert!(report.summary().starts_with("0 req"));
    }

    /// Satellite: input-length validation happens at submit, with a typed
    /// error — a short or oversized request never reaches a worker.
    #[test]
    fn invalid_input_length_rejected_at_submit() {
        let model = tiny_fc_model();
        let resolver = crate::ops::OpResolver::with_reference_ops();
        let cfg = ServingConfig { workers: 1, ..Default::default() };
        let mut rejected = Vec::new();
        let report = run_with_feeder(
            &model,
            &resolver,
            cfg,
            2,
            |sub| {
                rejected.push(sub.submit(Request::new(0, vec![0i8; 3]))); // short
                rejected.push(sub.submit(Request::new(1, vec![0i8; 5]))); // long
                assert!(sub.submit(Request::new(2, vec![0i8; 4])).is_ok());
            },
            |_| {},
        )
        .unwrap();
        assert_eq!(report.completed, 1);
        assert_eq!(report.faults.rejected_submits, 2);
        assert!(matches!(
            rejected[0],
            Err(Error::InvalidInput { id: 0, expected: 4, got: 3 })
        ));
        assert!(matches!(
            rejected[1],
            Err(Error::InvalidInput { id: 1, expected: 4, got: 5 })
        ));
    }
}
