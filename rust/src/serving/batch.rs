//! Request coalescing for the batched serving path: the latency-bounded
//! gather window plus the lane pack/scatter helpers.
//!
//! A worker pull with [`super::ServingConfig::max_batch`] > 1 blocks for
//! the *first* request, then holds the queue lock while it gathers up to
//! `max_batch - 1` more inside [`super::ServingConfig::batch_window`]
//! ([`gather`]). Every request in one queue shares the compatibility key
//! — submit validated the input length against the model — so any
//! waiting request may join the batch. The batch is then examined
//! (expired members shed individually), packed one request per lane
//! ([`pack_lanes`]), run through one batched invoke, and scattered back
//! to per-request responses ([`lane`]). The copy helpers are on the
//! allocation-free warm path: forming a batch moves bytes, it never
//! allocates.
//!
//! This file is on the `no_panic` lint surface: helpers return
//! `bool`/`Option` instead of panicking on contract violations, and the
//! callers count those as invoke errors.

use super::Request;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Gather a batch: `first` plus up to `max_batch - 1` more requests that
/// arrive within `window`. Returns early when the batch fills or the
/// queue closes; with `max_batch <= 1` it returns `[first]` immediately
/// and never waits, so an unbatched config pays no window latency.
pub(crate) fn gather(
    rx: &Receiver<Request>,
    first: Request,
    max_batch: usize,
    window: Duration,
) -> Vec<Request> {
    let cap = max_batch.max(1);
    let mut batch = Vec::with_capacity(cap);
    batch.push(first);
    if cap == 1 {
        return batch;
    }
    let expiry = Instant::now() + window;
    while batch.len() < cap {
        let remaining = expiry.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            break;
        }
        match rx.recv_timeout(remaining) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    batch
}

// lint:alloc_free — the batch-formation hot path: pure slice copies into
// the batched input view, one lane per member.
/// Copy each member's input into its lane of the batched input slice
/// (lane `b` of an n-element tensor is `[b*n, (b+1)*n)`). Returns false
/// — without touching `dst` further — when the lane arithmetic does not
/// line up (a member input of the wrong length; unreachable after
/// submit-time validation, but this is the no-panic surface).
pub(crate) fn pack_lanes(dst: &mut [i8], members: &[Request]) -> bool {
    if members.is_empty() || dst.len() % members.len() != 0 {
        return false;
    }
    let lane_n = dst.len() / members.len();
    if lane_n == 0 {
        // chunks_exact_mut(0) would panic; a zero-size lane is a
        // contract violation, not a batch to serve.
        return false;
    }
    for (lane, req) in dst.chunks_exact_mut(lane_n).zip(members) {
        if req.input.len() != lane_n {
            return false;
        }
        lane.copy_from_slice(&req.input);
    }
    true
}

// lint:alloc_free — the scatter hot path: a bounds-checked subslice, no
// copies (the caller copies straight into its response buffer).
/// Lane `b` of a batched output slice whose per-request element count is
/// `lane_n`. `None` when the lane falls outside the slice.
pub(crate) fn lane(batched: &[i8], lane_n: usize, b: usize) -> Option<&[i8]> {
    let start = b.checked_mul(lane_n)?;
    let end = start.checked_add(lane_n)?;
    batched.get(start..end)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn gather_fills_from_waiting_requests() {
        let (tx, rx) = sync_channel::<Request>(8);
        for id in 0..5u64 {
            tx.send(Request::new(id, vec![0i8; 2])).unwrap();
        }
        let first = rx.recv().unwrap();
        let batch = gather(&rx, first, 3, Duration::from_secs(5));
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2], "gather stops at max_batch");
        // The rest stay queued for the next pull.
        assert_eq!(rx.recv().unwrap().id, 3);
    }

    #[test]
    fn gather_window_bounds_the_wait() {
        let (tx, rx) = sync_channel::<Request>(8);
        tx.send(Request::new(7, vec![1i8])).unwrap();
        let first = rx.recv().unwrap();
        let t0 = Instant::now();
        let batch = gather(&rx, first, 4, Duration::from_millis(20));
        assert_eq!(batch.len(), 1, "nothing else arrived");
        assert!(t0.elapsed() >= Duration::from_millis(20), "waited out the window");
        assert!(t0.elapsed() < Duration::from_secs(2), "window bounded the wait");
    }

    #[test]
    fn gather_unbatched_never_waits() {
        let (_tx, rx) = sync_channel::<Request>(8);
        let t0 = Instant::now();
        let batch = gather(&rx, Request::new(1, vec![]), 1, Duration::from_secs(60));
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn gather_returns_early_when_queue_closes() {
        let (tx, rx) = sync_channel::<Request>(8);
        tx.send(Request::new(0, vec![])).unwrap();
        drop(tx);
        let first = rx.recv().unwrap();
        let t0 = Instant::now();
        let batch = gather(&rx, first, 8, Duration::from_secs(60));
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(2), "disconnect ends the window");
    }

    #[test]
    fn pack_lanes_lays_members_contiguously() {
        let members =
            vec![Request::new(0, vec![1i8, 2]), Request::new(1, vec![3, 4]), Request::new(2, vec![5, 6])];
        let mut dst = [0i8; 6];
        assert!(pack_lanes(&mut dst, &members));
        assert_eq!(dst, [1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn pack_lanes_rejects_mismatched_lengths() {
        let members = vec![Request::new(0, vec![1i8, 2]), Request::new(1, vec![3])];
        let mut dst = [0i8; 4];
        assert!(!pack_lanes(&mut dst, &members), "short member input");
        assert!(!pack_lanes(&mut dst[..3], &members), "non-divisible batched slice");
        assert!(!pack_lanes(&mut dst, &[]), "empty batch");
        assert!(!pack_lanes(&mut dst[..0], &members), "zero-size lanes rejected, no panic");
    }

    #[test]
    fn lane_slices_and_bounds_checks() {
        let out = [1i8, 2, 3, 4, 5, 6];
        assert_eq!(lane(&out, 2, 0), Some(&out[0..2]));
        assert_eq!(lane(&out, 2, 2), Some(&out[4..6]));
        assert_eq!(lane(&out, 2, 3), None, "past the end");
        assert_eq!(lane(&out, usize::MAX, 2), None, "overflow-safe");
    }
}
