//! Versioned model registry: zero-downtime publish → canary → hot-swap
//! → automatic rollback (see the lifecycle state machine in the
//! [`super`] module docs).
//!
//! Built on the [`PreparedModel`]/[`ExecState`] split: a published
//! version is one shared `Arc<PreparedModel>` (packed weights, folded
//! biases, compiled XLA executables, memory plan — charged once per
//! version), and each worker owns only a cheap per-version [`ExecState`].
//! Swapping a fleet to a new version is therefore an `Arc` pointer swap
//! plus one zeroed buffer per worker — no populate pass, no XLA
//! recompile, no draining.
//!
//! Workers re-read the registry's live pointer at every queue pull, so a
//! promotion takes effect between requests: in-flight invokes finish on
//! the version they started with and nothing is dropped. A worker whose
//! invoke panics drops only its own `ExecState` (the shared model is
//! immutable at invoke time) and rebuilds it on the next pull — that
//! *is* the respawn, which is why registry workers never die from
//! panics; they die only when every version is retired.
//!
//! One sharing caveat: vendor/XLA kernels that key staged state by op
//! index (e.g. `runtime::XlaFcKernel`) share that state across every
//! model built from the same resolver instance. Versions with different
//! weights should be published through their own kernel registrations if
//! offload matters; otherwise the loser of a populate race detects the
//! weight mismatch at invoke and takes the bit-exact CPU fallback.

use super::{
    FaultTaxonomy, FleetShared, Request, Response, ServingConfig, ServingReport, Submitter,
};
use crate::error::{Error, Result};
use crate::interpreter::{ExecState, PreparedModel};
use crate::ops::OpResolver;
use crate::schema::Model;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// One published model version: an immutable shared [`PreparedModel`]
/// plus lifecycle bookkeeping.
pub struct ModelVersion {
    name: String,
    /// Monotonic promotion sequence number (workers detect swaps by
    /// comparing it, so republishing an old name still swaps).
    seq: u64,
    prepared: Arc<PreparedModel>,
    /// Post-promotion panics charged against this version's respawn
    /// budget.
    panics: AtomicUsize,
}

impl ModelVersion {
    /// Version name as passed to `publish`.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The shared prepared model.
    pub fn prepared(&self) -> &Arc<PreparedModel> {
        &self.prepared
    }

    /// Post-promotion panics charged to this version so far.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }
}

impl std::fmt::Debug for ModelVersion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelVersion")
            .field("name", &self.name)
            .field("seq", &self.seq)
            .field("panics", &self.panics.load(Ordering::SeqCst))
            .finish()
    }
}

/// Canary-phase configuration for [`ModelRegistry::publish`].
#[derive(Debug, Clone)]
pub struct CanaryConfig {
    /// Number of shadow invokes on deterministic random inputs.
    pub shadow_invokes: usize,
    /// Seed for the shadow-input generator (same seed, same canary).
    pub seed: u64,
    /// Golden health probes: (input, expected output) pairs the
    /// candidate must reproduce exactly.
    pub golden: Vec<(Vec<i8>, Vec<i8>)>,
    /// Compare shadow outputs bit-exactly against the live version.
    /// Disable when publishing an intentionally different model (e.g. a
    /// retrained version) — golden probes then carry the health check.
    pub require_bit_exact: bool,
}

impl Default for CanaryConfig {
    fn default() -> Self {
        CanaryConfig { shadow_invokes: 8, seed: 0xCA7A, golden: Vec::new(), require_bit_exact: true }
    }
}

/// Snapshot of a registry's lifecycle counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LifecycleStats {
    /// `publish` calls (accepted or rejected).
    pub publishes: usize,
    /// Publishes rejected while building the `PreparedModel`.
    pub prepare_failures: usize,
    /// Publishes rejected by the canary phase.
    pub canary_rejects: usize,
    /// Automatic rollbacks to the last-known-good version.
    pub rollbacks: usize,
}

/// What [`ModelRegistry::exhaust`] did about a version whose respawn
/// budget ran out.
enum ExhaustOutcome {
    /// The bad version was live; a previous good version was reinstated.
    RolledBack(Arc<ModelVersion>),
    /// The bad version was already demoted; this is the current live one.
    AlreadyHandled(Option<Arc<ModelVersion>>),
    /// The bad version was live and no good version remains.
    Terminal,
}

/// Versioned registry of published models. All methods take `&self`
/// (internal locking), so one registry is shared by the feeder
/// (publishing) and the worker fleet (serving) simultaneously.
pub struct ModelRegistry {
    /// The currently serving version, if any. Lock order: `live` before
    /// `history`, everywhere.
    live: RwLock<Option<Arc<ModelVersion>>>,
    /// Known-good versions in promotion order (a version is good once it
    /// passes canary; it leaves history when its budget exhausts).
    history: Mutex<Vec<Arc<ModelVersion>>>,
    seq: AtomicU64,
    publishes: AtomicUsize,
    prepare_failures: AtomicUsize,
    canary_rejects: AtomicUsize,
    rollbacks: AtomicUsize,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    /// Empty registry (publish a version before serving).
    pub fn new() -> Self {
        ModelRegistry {
            live: RwLock::new(None),
            history: Mutex::new(Vec::new()),
            seq: AtomicU64::new(0),
            publishes: AtomicUsize::new(0),
            prepare_failures: AtomicUsize::new(0),
            canary_rejects: AtomicUsize::new(0),
            rollbacks: AtomicUsize::new(0),
        }
    }

    /// The currently live version, if any.
    pub fn live(&self) -> Option<Arc<ModelVersion>> {
        self.live.read().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Name of the currently live version, if any.
    pub fn active_version(&self) -> Option<String> {
        self.live().map(|v| v.name.clone())
    }

    /// Lifecycle counter snapshot.
    pub fn stats(&self) -> LifecycleStats {
        LifecycleStats {
            publishes: self.publishes.load(Ordering::SeqCst),
            prepare_failures: self.prepare_failures.load(Ordering::SeqCst),
            canary_rejects: self.canary_rejects.load(Ordering::SeqCst),
            rollbacks: self.rollbacks.load(Ordering::SeqCst),
        }
    }

    fn reject_prepare(&self, version: &str, reason: String) -> Error {
        self.prepare_failures.fetch_add(1, Ordering::SeqCst);
        Error::PublishRejected { version: version.to_string(), stage: "prepare", reason }
    }

    fn reject_canary(&self, version: &str, reason: String) -> Error {
        self.canary_rejects.fetch_add(1, Ordering::SeqCst);
        Error::PublishRejected { version: version.to_string(), stage: "canary", reason }
    }

    /// Publish a new model version: **Preparing** (full prepare → plan →
    /// populate, off the hot path) then **Canary** (shadow invokes
    /// compared against the live version, plus golden probes), then
    /// atomic promotion to **Live**. Any failure leaves the previously
    /// live version serving untouched and returns
    /// [`Error::PublishRejected`].
    pub fn publish(
        &self,
        name: &str,
        model: Arc<Model>,
        resolver: &OpResolver,
        canary: &CanaryConfig,
    ) -> Result<Arc<ModelVersion>> {
        self.publishes.fetch_add(1, Ordering::SeqCst);

        // --- Preparing ------------------------------------------------
        if let Some(reason) = crate::faults::prepare_fail_point(name) {
            return Err(self.reject_prepare(name, reason));
        }
        let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            PreparedModel::new(model, resolver)
        }));
        let prepared = match built {
            Ok(Ok(pm)) => Arc::new(pm),
            Ok(Err(e)) => return Err(self.reject_prepare(name, e.to_string())),
            Err(_) => return Err(self.reject_prepare(name, "panic during prepare".into())),
        };
        let m = prepared.model();
        if m.inputs().is_empty() || m.outputs().is_empty() {
            return Err(self.reject_prepare(name, "model has no inputs or outputs".into()));
        }
        let in_len = match m.inputs().first().and_then(|&i| m.tensors().get(i as usize)) {
            Some(t) => t.num_elements(),
            None => {
                return Err(
                    self.reject_prepare(name, "model input tensor index out of range".into())
                )
            }
        };

        // --- Canary ---------------------------------------------------
        // The candidate must be I/O-compatible with the live version:
        // the swap happens underneath submitters whose inputs were
        // validated against the live shape. Full signature — every input
        // and output tensor's dtype and shape — so a candidate with extra
        // I/O tensors, a reshaped tensor with the same element count
        // ([2,3] vs [3,2]), or a different dtype cannot slip through.
        let live = self.live();
        if let Some(live) = &live {
            let lm = live.prepared.model();
            let (cand_sig, live_sig) = (io_signature(m), io_signature(lm));
            if cand_sig != live_sig {
                return Err(self.reject_canary(
                    name,
                    format!(
                        "I/O signature {cand_sig} incompatible with live version \
                         '{}' ({live_sig})",
                        live.name
                    ),
                ));
            }
        }
        // Seq of the version the canary compares against; promotion
        // re-checks it so a publish can never clobber a live version it
        // was not canaried against.
        let canary_basis = live.as_ref().map(|v| v.seq);
        let mut rng = crate::testutil::Rng::seeded(canary.seed);
        let mut live_es = live.as_ref().map(|v| v.prepared.exec_state());
        let mut cand_es = prepared.exec_state();
        for shadow in 0..canary.shadow_invokes {
            let mut input = vec![0i8; in_len];
            rng.fill_i8(&mut input);
            let got = match shadow_invoke(&prepared, &mut cand_es, &input) {
                Ok(out) => out,
                Err(why) => {
                    return Err(self.reject_canary(name, format!("shadow invoke {shadow}: {why}")))
                }
            };
            if crate::faults::canary_diverge_point(name) {
                return Err(self.reject_canary(
                    name,
                    format!("injected fault: canary divergence at shadow invoke {shadow}"),
                ));
            }
            if canary.require_bit_exact {
                if let (Some(live), Some(les)) = (&live, live_es.as_mut()) {
                    // A live-side invoke error says nothing about the
                    // candidate; only a successful live output gates it.
                    if let Ok(want) = shadow_invoke(&live.prepared, les, &input) {
                        if want != got {
                            return Err(self.reject_canary(
                                name,
                                format!(
                                    "shadow invoke {shadow} diverged from live version '{}'",
                                    live.name
                                ),
                            ));
                        }
                    }
                }
            }
        }
        for (probe, (input, want)) in canary.golden.iter().enumerate() {
            if input.len() != in_len {
                return Err(self.reject_canary(
                    name,
                    format!("golden probe {probe}: input is {} elements, model expects {in_len}", input.len()),
                ));
            }
            match shadow_invoke(&prepared, &mut cand_es, input) {
                Ok(got) if &got == want => {}
                Ok(_) => {
                    return Err(
                        self.reject_canary(name, format!("golden probe {probe} mismatched"))
                    )
                }
                Err(why) => {
                    return Err(self.reject_canary(name, format!("golden probe {probe}: {why}")))
                }
            }
        }

        // --- Promote to Live ------------------------------------------
        let version = Arc::new(ModelVersion {
            name: name.to_string(),
            seq: self.seq.fetch_add(1, Ordering::SeqCst) + 1,
            prepared,
            panics: AtomicUsize::new(0),
        });
        {
            let mut live = self.live.write().unwrap_or_else(|p| p.into_inner());
            // The registry is shared (&self): a concurrent publish or an
            // automatic rollback may have changed the live version since
            // the canary snapshot. Promoting anyway would install a
            // version that was never compared against the now-current
            // live one — reject instead and let the caller republish.
            if live.as_ref().map(|v| v.seq) != canary_basis {
                return Err(self.reject_canary(
                    name,
                    format!(
                        "live version changed during canary (now '{}'); republish",
                        live.as_ref().map(|v| v.name.as_str()).unwrap_or("<none>")
                    ),
                ));
            }
            let mut history = self.history.lock().unwrap_or_else(|p| p.into_inner());
            *live = Some(Arc::clone(&version));
            history.push(Arc::clone(&version));
        }
        Ok(version)
    }

    /// A promoted version exhausted its respawn budget: demote it and
    /// reinstate the last-known-good version (**RolledBack**), or report
    /// terminal state when no good version remains.
    fn exhaust(&self, bad: &Arc<ModelVersion>) -> ExhaustOutcome {
        let mut live = self.live.write().unwrap_or_else(|p| p.into_inner());
        let mut history = self.history.lock().unwrap_or_else(|p| p.into_inner());
        history.retain(|v| v.seq != bad.seq);
        let live_is_bad = live.as_ref().map(|v| v.seq == bad.seq).unwrap_or(false);
        if !live_is_bad {
            // Another worker already rolled back (or a newer version was
            // promoted meanwhile); nothing to do.
            return ExhaustOutcome::AlreadyHandled(live.clone());
        }
        match history.last() {
            Some(good) => {
                let good = Arc::clone(good);
                *live = Some(Arc::clone(&good));
                self.rollbacks.fetch_add(1, Ordering::SeqCst);
                ExhaustOutcome::RolledBack(good)
            }
            None => {
                *live = None;
                ExhaustOutcome::Terminal
            }
        }
    }
}

/// Render a model's full graph-I/O signature — dtype and shape of every
/// input and output tensor, in order — as a canonical string. The
/// publish-time compatibility gate compares these strings: dtype and
/// shape rendering are both injective, so equal strings mean equal
/// signatures.
fn io_signature(m: &Model) -> String {
    let side = |list: &[i32]| -> String {
        list.iter()
            .map(|&t| {
                let meta = &m.tensors()[t as usize];
                format!("{}{}", meta.dtype, meta.shape)
            })
            .collect::<Vec<_>>()
            .join(",")
    };
    format!("{} -> {}", side(m.inputs()), side(m.outputs()))
}

/// One canary/golden invoke through a private [`ExecState`], with panic
/// containment (a panicking candidate must reject, not unwind into the
/// publisher).
fn shadow_invoke(
    prepared: &Arc<PreparedModel>,
    es: &mut ExecState,
    input: &[i8],
) -> std::result::Result<Vec<i8>, String> {
    let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> Result<Vec<i8>> {
            prepared.input_mut(es, 0)?.copy_from_i8(input)?;
            prepared.invoke(es)?;
            Ok(prepared.output(es, 0)?.as_i8()?.to_vec())
        },
    ));
    match unwound {
        Ok(Ok(out)) => Ok(out),
        Ok(Err(e)) => Err(e.to_string()),
        Err(_) => Err("panic during invoke".to_string()),
    }
}

/// Registry-backed closed loop: like [`super::run_closed_loop`] but
/// serving whatever version the registry has live at each queue pull.
pub fn run_registry_closed_loop(
    registry: &ModelRegistry,
    cfg: ServingConfig,
    requests: Vec<Request>,
    expected_out_len: usize,
) -> Result<ServingReport> {
    let timeout = cfg.submit_timeout;
    run_registry_with_feeder(
        registry,
        cfg,
        expected_out_len,
        move |sub| {
            for r in requests {
                let _ = match timeout {
                    Some(t) => sub.submit_timeout(r, t),
                    None => sub.submit(r),
                };
            }
        },
        |_resp| {},
    )
}

/// Run a serving session over a [`ModelRegistry`] with a caller-supplied
/// feeder (which may keep publishing versions while the fleet serves —
/// that is the point).
///
/// Differences from [`super::run_with_feeder`]:
///
/// * Workers hold an `Arc` to the live [`ModelVersion`] plus a private
///   [`ExecState`]; at every queue pull they re-read the registry and
///   swap to a newly promoted version by rebuilding only the
///   `ExecState` (no populate pass — that ran once at publish).
/// * A caught panic drops the worker's `ExecState` (the poisoned
///   per-worker state) and charges the **version's** respawn budget;
///   exhausting it triggers [`ModelRegistry::exhaust`] — automatic
///   rollback to last-known-good — and only a registry with no good
///   version left opens the breaker.
/// * The report's `canary_rejects` / `rollbacks` / `active_version`
///   rows are filled from the registry's lifecycle counters (as deltas
///   over this run).
pub fn run_registry_with_feeder<F>(
    registry: &ModelRegistry,
    cfg: ServingConfig,
    expected_out_len: usize,
    feeder: F,
    mut on_response: impl FnMut(&Response),
) -> Result<ServingReport>
where
    F: FnOnce(&Submitter<'_>) + Send,
{
    if cfg.workers == 0 {
        return Err(Error::Serving("need at least one worker".into()));
    }
    let initial = registry
        .live()
        .ok_or_else(|| Error::Serving("publish a model version before serving".into()))?;
    let m = initial.prepared.model();
    // Publish validated the input signature, but serving must not trust
    // that across versions: resolve defensively instead of indexing.
    let expected_in_len = m
        .inputs()
        .first()
        .and_then(|&i| m.tensors().get(i as usize))
        .map(|t| t.num_elements())
        .ok_or_else(|| Error::Serving("live model has no resolvable input tensor".into()))?;
    drop(initial);

    let shared = FleetShared::new(&cfg, expected_in_len);
    let stats_before = registry.stats();
    let degrades_before = crate::runtime::degrade_events();
    // Requests pulled by a worker that then found every version retired
    // (they were accepted but can never be served).
    let dropped_after_pull = AtomicUsize::new(0);

    let (req_tx, req_rx): (SyncSender<Request>, Receiver<Request>) =
        sync_channel(cfg.queue_depth);
    let req_rx = Mutex::new(req_rx);
    let (resp_tx, resp_rx) = std::sync::mpsc::channel::<Response>();

    let t0 = Instant::now();
    let mut report = std::thread::scope(|scope| -> Result<ServingReport> {
        for w in 0..cfg.workers {
            let req_rx = &req_rx;
            let resp_tx = resp_tx.clone();
            let shared = &shared;
            let dropped_after_pull = &dropped_after_pull;
            scope.spawn(move || {
                shared.started.fetch_add(1, Ordering::SeqCst);
                let mut abnormal = false;
                // The worker's current (version, private exec state).
                // Rebuilding this pair IS the respawn: the shared
                // PreparedModel is immutable at invoke time, so a panic
                // can poison only the ExecState.
                let mut current: Option<(Arc<ModelVersion>, ExecState)> = None;
                'pull: loop {
                    // GATHER: with max_batch = 1 this returns the single
                    // pulled request immediately (no window wait) — the
                    // pre-batching behavior, verbatim.
                    let gathered = {
                        let rx = req_rx.lock().unwrap_or_else(|p| p.into_inner());
                        let first = match rx.recv() {
                            Ok(r) => r,
                            Err(_) => break 'pull,
                        };
                        super::batch::gather(&rx, first, cfg.max_batch, cfg.batch_window)
                    };
                    // EXAMINE: shed expired members individually; their
                    // batchmates stay pending and are served.
                    let now = Instant::now();
                    let mut pending: Vec<Request> = Vec::with_capacity(gathered.len());
                    for req in gathered {
                        if let Some(d) = req.deadline {
                            if now >= d {
                                shared.deadline_misses.fetch_add(1, Ordering::SeqCst);
                                continue;
                            }
                        }
                        pending.push(req);
                    }
                    if pending.is_empty() {
                        continue;
                    }
                    crate::faults::queue_stall_point();
                    // Serve the gathered batch in chunks no larger than
                    // the live version's own batch capability (a version
                    // published without batch support serves lane by
                    // lane — correctness never depends on the publish
                    // options).
                    let mut next = 0usize;
                    while next < pending.len() {
                        // Version swap point: promotions and rollbacks
                        // take effect here, between (sub-)batches.
                        let Some(live) = registry.live() else {
                            // Every version retired: the rest of this
                            // batch was accepted but can never be served.
                            dropped_after_pull
                                .fetch_add(pending.len() - next, Ordering::SeqCst);
                            shared.breaker_open.store(true, Ordering::SeqCst);
                            abnormal = true;
                            break 'pull;
                        };
                        let stale = match &current {
                            Some((v, _)) => v.seq != live.seq,
                            None => true,
                        };
                        if stale {
                            current = Some((Arc::clone(&live), live.prepared.exec_state()));
                        }
                        let Some((cur, es)) = current.as_mut() else { break 'pull };
                        let ver = Arc::clone(cur);
                        let pm = &ver.prepared;
                        let cap = pm.max_batch().max(1);
                        let end = (next + cap).min(pending.len());
                        let chunk = &pending[next..end];
                        let m = chunk.len();
                        // INVOKE: one batched pass for this chunk.
                        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || -> Result<Vec<i8>> {
                                crate::faults::version_panic_point(ver.name());
                                let mut view = pm.input_mut_batched(es, 0, m)?;
                                if !super::batch::pack_lanes(view.as_i8_mut()?, chunk) {
                                    return Err(Error::Serving(
                                        "batch member input length mismatch".into(),
                                    ));
                                }
                                pm.invoke_batched(es, m)?;
                                Ok(pm.output_batched(es, 0, m)?.as_i8()?.to_vec())
                            },
                        ));
                        next = end;
                        match unwound {
                            Ok(Ok(output)) => {
                                // SCATTER: lateness and latency from each
                                // request's own `enqueued`, never
                                // batch-formation time.
                                let lane_n = output.len() / m;
                                for (b, req) in chunk.iter().enumerate() {
                                    if let Some(d) = req.deadline {
                                        if Instant::now() >= d {
                                            shared
                                                .late_completions
                                                .fetch_add(1, Ordering::SeqCst);
                                        }
                                    }
                                    let Some(out) = super::batch::lane(&output, lane_n, b)
                                    else {
                                        shared.invoke_errors.fetch_add(1, Ordering::SeqCst);
                                        continue;
                                    };
                                    let resp = Response {
                                        id: req.id,
                                        output: out.to_vec(),
                                        latency: req.enqueued.elapsed(),
                                        worker: w,
                                    };
                                    if resp_tx.send(resp).is_err() {
                                        break 'pull;
                                    }
                                }
                            }
                            Ok(Err(_)) => {
                                // A clean error fails every chunk member
                                // as its own counted loss.
                                shared.invoke_errors.fetch_add(m, Ordering::SeqCst);
                            }
                            Err(_payload) => {
                                // One supervision event that loses the
                                // whole chunk's membership; batchmates in
                                // later chunks still get served.
                                shared.panics.fetch_add(1, Ordering::SeqCst);
                                shared.panic_lost.fetch_add(m, Ordering::SeqCst);
                                shared.poisoned_arenas.fetch_add(1, Ordering::SeqCst);
                                // Drop the poisoned ExecState; the next
                                // chunk/pull rebuilds one (the respawn).
                                current = None;
                                let used = ver.panics.fetch_add(1, Ordering::SeqCst);
                                if used >= shared.max_respawns {
                                    match registry.exhaust(&ver) {
                                        ExhaustOutcome::RolledBack(_)
                                        | ExhaustOutcome::AlreadyHandled(Some(_)) => {
                                            // A good version serves from
                                            // the next chunk; the worker
                                            // lives on.
                                        }
                                        ExhaustOutcome::AlreadyHandled(None)
                                        | ExhaustOutcome::Terminal => {
                                            shared.breaker_open.store(true, Ordering::SeqCst);
                                            dropped_after_pull.fetch_add(
                                                pending.len() - next,
                                                Ordering::SeqCst,
                                            );
                                            abnormal = true;
                                            break 'pull;
                                        }
                                    }
                                } else {
                                    shared.respawns_used.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                        }
                    }
                }
                if shared.live.fetch_sub(1, Ordering::SeqCst) == 1 && abnormal {
                    shared.breaker_open.store(true, Ordering::SeqCst);
                }
            });
        }
        drop(resp_tx);

        let submitter = Submitter { tx: req_tx, shared: &shared };
        scope.spawn(move || {
            feeder(&submitter);
            drop(submitter);
        });

        let mut col = super::Collector::new(cfg.workers);
        for resp in resp_rx.iter() {
            if resp.output.len() != expected_out_len {
                shared.breaker_open.store(true, Ordering::SeqCst);
                return Err(Error::Serving(format!(
                    "response {} has {} outputs, expected {expected_out_len}",
                    resp.id,
                    resp.output.len()
                )));
            }
            on_response(&resp);
            col.record(&resp);
        }
        let wall = t0.elapsed();

        let mut dropped = dropped_after_pull.load(Ordering::SeqCst);
        {
            let rx = req_rx.lock().unwrap_or_else(|p| p.into_inner());
            while rx.try_recv().is_ok() {
                dropped += 1;
            }
        }

        let [p50, p95, p99] = col.percentiles();
        let mut faults: FaultTaxonomy = shared.taxonomy();
        faults.dropped = dropped;
        let stats_after = registry.stats();
        faults.canary_rejects = stats_after.canary_rejects - stats_before.canary_rejects;
        faults.rollbacks = stats_after.rollbacks - stats_before.rollbacks;
        Ok(ServingReport {
            completed: col.completed,
            wall,
            throughput_rps: if col.completed == 0 {
                0.0
            } else {
                col.completed as f64 / wall.as_secs_f64().max(1e-9)
            },
            latency_p50: p50,
            latency_p95: p95,
            latency_p99: p99,
            per_worker: col.per_worker,
            cold_start_ns: col.cold_start_ns,
            faults,
            breaker_open: shared.breaker_open.load(Ordering::SeqCst),
            active_version: registry.active_version(),
        })
    })?;
    report.faults.degraded_ops =
        (crate::runtime::degrade_events() - degrades_before) as usize;
    Ok(report)
}
