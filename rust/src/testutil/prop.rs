//! Miniature property-testing driver (proptest substitute, DESIGN.md §6.6).
//!
//! Runs a property over N generated cases from a deterministic [`Rng`];
//! on failure it reports the case index and seed so the exact case can be
//! replayed by construction. No shrinking — generators here are small
//! enough that the raw case is readable.

use super::rng::Rng;

/// Configuration for a property check.
#[derive(Debug, Clone, Copy)]
pub struct Cases {
    /// How many cases to run.
    pub count: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for Cases {
    fn default() -> Self {
        Cases { count: 256, seed: 0xC0FFEE }
    }
}

impl Cases {
    /// `count` cases with the default seed.
    pub fn n(count: usize) -> Self {
        Cases { count, ..Default::default() }
    }
}

/// Run `property` over generated cases. The property receives a fresh
/// seeded [`Rng`] per case and returns `Err(description)` to fail.
///
/// # Panics
/// Panics (failing the enclosing `#[test]`) on the first failing case,
/// reporting the case index and its seed.
pub fn check<F>(cases: Cases, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cases.count {
        let case_seed = cases.seed.wrapping_add(i as u64);
        let mut rng = Rng::seeded(case_seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property failed at case {i}/{} (seed {case_seed:#x}): {msg}",
                cases.count
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Cases::n(50), |rng| {
            let v = rng.below(100);
            if v < 100 {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failing_property_reports_case() {
        check(Cases::n(50), |rng| {
            let v = rng.below(10);
            if v != 7 {
                Ok(())
            } else {
                Err("hit the bad value".into())
            }
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut seen_a = Vec::new();
        check(Cases { count: 5, seed: 99 }, |rng| {
            seen_a.push(rng.next_u64());
            Ok(())
        });
        let mut seen_b = Vec::new();
        check(Cases { count: 5, seed: 99 }, |rng| {
            seen_b.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }
}
