//! Deterministic PRNG for tests, property checks, and workload generation.
//!
//! xoshiro256** — fast, well-distributed, and seedable so every test and
//! benchmark is exactly reproducible (no `rand` crate in the offline
//! registry, and determinism is a feature here anyway: synthetic workloads
//! must be identical between Python export and Rust execution).

/// A seeded xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a seed; any value (including 0) is fine.
    pub fn seeded(seed: u64) -> Self {
        // SplitMix64 expansion of the seed, as recommended by the xoshiro
        // authors, so similar seeds give unrelated streams.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next usize.
    pub fn next_usize(&mut self) -> usize {
        self.next_u64() as usize
    }

    /// Uniform in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u64;
        lo + (self.next_u64() % span) as i32
    }

    /// Random i8 over the full range.
    pub fn next_i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Fill a slice with random i8 values.
    pub fn fill_i8(&mut self, out: &mut [i8]) {
        for v in out {
            *v = self.next_i8();
        }
    }

    /// Random boolean with probability `p` of `true`.
    pub fn chance(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::seeded(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seeded(9);
        let mut lo = 1.0f32;
        let mut hi = 0.0f32;
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            lo = lo.min(v);
            hi = hi.max(v);
        }
        // Reasonable spread.
        assert!(lo < 0.01 && hi > 0.99);
    }

    #[test]
    fn range_i32_inclusive() {
        let mut r = Rng::seeded(11);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i32(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }
}
