//! Test and benchmark support utilities.
//!
//! The offline crate registry for this environment carries neither
//! `proptest` nor `criterion`, so this module provides the small pieces we
//! actually need (DESIGN.md §6.6): a deterministic PRNG, a miniature
//! property-testing driver with failure-case reporting, and warmup/statistics
//! helpers used by the custom-harness benches.

mod bench;
mod prop;
mod rng;

pub use bench::{black_box, fmt_kb, fmt_kcycles, BenchStats, Bencher};
pub use prop::{check, Cases};
pub use rng::Rng;
