//! Benchmark harness helpers (criterion substitute, DESIGN.md §6.6).
//!
//! The benches in `rust/benches/` are `harness = false` binaries; they use
//! [`Bencher`] for warmup + timed iterations and [`BenchStats`] for simple
//! robust statistics (median / p95 over per-iteration wall times).

use std::time::{Duration, Instant};

/// Summary statistics over per-iteration timings.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    /// Number of measured iterations.
    pub iters: usize,
    /// Median iteration time.
    pub median: Duration,
    /// Mean iteration time.
    pub mean: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
    /// Minimum iteration time.
    pub min: Duration,
    /// Maximum iteration time.
    pub max: Duration,
}

impl BenchStats {
    /// Compute stats from raw per-iteration durations.
    pub fn from_samples(mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        BenchStats {
            iters: n,
            median: samples[n / 2],
            mean: total / n as u32,
            p95: samples[(n * 95 / 100).min(n - 1)],
            min: samples[0],
            max: samples[n - 1],
        }
    }

    /// Render as a one-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "median {:>10.3?}  mean {:>10.3?}  p95 {:>10.3?}  (n={})",
            self.median, self.mean, self.p95, self.iters
        )
    }
}

/// Warmup-then-measure bench driver.
pub struct Bencher {
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Measurement wall-clock budget.
    pub measure: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    /// Minimum measured iterations (even past the time budget).
    pub min_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1000),
            max_iters: 100_000,
            min_iters: 5,
        }
    }
}

impl Bencher {
    /// A quick configuration for heavyweight workloads (e.g. whole-model
    /// VWW invocations) where each iteration is tens of milliseconds.
    pub fn heavy() -> Self {
        Bencher {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(1500),
            max_iters: 500,
            min_iters: 3,
        }
    }

    /// Run `f` with warmup, then measure per-iteration wall time.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        BenchStats::from_samples(samples)
    }
}

/// Prevent the optimizer from discarding a computed value.
/// (std::hint::black_box wrapper kept for call-site clarity.)
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Format a byte count the way the paper's Table 2 does (kB with 2 d.p.).
pub fn fmt_kb(bytes: usize) -> String {
    if bytes < 1024 {
        format!("{bytes} bytes")
    } else {
        format!("{:.2} kB", bytes as f64 / 1024.0)
    }
}

/// Format a simulated cycle count the way Figure 6 does ("18,990.8K").
pub fn fmt_kcycles(cycles: u64) -> String {
    let k = cycles as f64 / 1000.0;
    let whole = k.trunc() as u64;
    let frac = ((k - k.trunc()) * 10.0).round() as u64;
    // Thousands separators on the whole part.
    let s = whole.to_string();
    let mut grouped = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i).is_multiple_of(3) {
            grouped.push(',');
        }
        grouped.push(c);
    }
    format!("{grouped}.{frac}K")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = BenchStats::from_samples(samples);
        assert_eq!(s.iters, 100);
        assert_eq!(s.min, Duration::from_micros(1));
        assert_eq!(s.max, Duration::from_micros(100));
        assert_eq!(s.median, Duration::from_micros(51));
        assert_eq!(s.p95, Duration::from_micros(96));
    }

    #[test]
    fn bencher_runs_minimum_iterations() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(1),
            max_iters: 1000,
            min_iters: 10,
        };
        let mut count = 0usize;
        let stats = b.run(|| count += 1);
        assert!(stats.iters >= 10);
        assert!(count >= stats.iters);
    }

    #[test]
    fn kb_formatting() {
        assert_eq!(fmt_kb(680), "680 bytes");
        assert_eq!(fmt_kb(9257), "9.04 kB");
    }

    #[test]
    fn kcycle_formatting() {
        assert_eq!(fmt_kcycles(18_990_800), "18,990.8K");
        assert_eq!(fmt_kcycles(45_100), "45.1K");
        assert_eq!(fmt_kcycles(900), "0.9K");
    }
}
