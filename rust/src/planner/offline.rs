//! Offline-planned tensor allocation (§4.4.2).
//!
//! "We allow the user to create a memory layout on a host before run time.
//! The memory layout is stored as model FlatBuffer metadata and contains an
//! array of fixed memory-arena offsets for an arbitrary number of variable
//! tensors." TMF carries the same array under
//! [`crate::schema::OFFLINE_PLAN_KEY`]: entry *i* is the fixed offset of
//! request *i*, or `-1` to let the fallback planner place it.
//!
//! Benefits reproduced here (and measured in `benches/bench_planner.rs`):
//! near-zero on-device planning work, user ownership of layout, and the
//! ability to pin specific tensors (e.g. to a faster memory bank).

use super::{resolve_aliases, BufferRequest, GreedyPlanner, MemoryPlan, MemoryPlanner};
use crate::error::{Error, Result};

/// Planner that applies host-computed fixed offsets, delegating unpinned
/// requests to [`GreedyPlanner`].
#[derive(Debug, Clone)]
pub struct OfflinePlanner {
    /// Offset per request; `-1` = let the fallback place it.
    pub fixed_offsets: Vec<i32>,
}

impl OfflinePlanner {
    /// Build from the model-metadata array.
    pub fn new(fixed_offsets: Vec<i32>) -> Self {
        OfflinePlanner { fixed_offsets }
    }

    /// Compute an offline plan on the host: run the greedy planner and
    /// freeze its offsets. This is the "host side" half of the feature
    /// (what `python/compile/export.py --offline-plan` does).
    pub fn precompute(requests: &[BufferRequest], align: usize) -> Result<Vec<i32>> {
        let plan = GreedyPlanner.plan(requests, align)?;
        Ok(plan.offsets.iter().map(|&o| o as i32).collect())
    }
}

impl MemoryPlanner for OfflinePlanner {
    fn plan(&self, requests: &[BufferRequest], align: usize) -> Result<MemoryPlan> {
        if self.fixed_offsets.len() != requests.len() {
            return Err(Error::PlanFailed(format!(
                "offline plan has {} entries for {} buffers",
                self.fixed_offsets.len(),
                requests.len()
            )));
        }
        let res = resolve_aliases(requests)?;
        let mut offsets = vec![0usize; requests.len()];
        let mut arena_size = 0usize;
        let mut unpinned: Vec<usize> = Vec::new();
        for (i, &fo) in self.fixed_offsets.iter().enumerate() {
            // Aliases are resolved after their root is placed; a pinned
            // alias entry is honored below and cross-checked against its
            // root by verify_plan.
            if res.root_of[i] != i {
                continue;
            }
            if fo < 0 {
                unpinned.push(i);
            } else {
                offsets[i] = fo as usize;
                arena_size = arena_size.max(fo as usize + requests[i].size);
            }
        }

        // Place unpinned buffers above the pinned region with greedy reuse
        // among themselves (simple and always valid; pinned regions stay
        // authoritative).
        if !unpinned.is_empty() {
            let base = (arena_size + align - 1) & !(align - 1);
            // The sub-list is indexed locally, so alias edges (which point
            // into the full list) must be stripped; merged lifetimes keep
            // each root reserved for its views' whole read window.
            let sub: Vec<BufferRequest> = unpinned
                .iter()
                .map(|&i| {
                    BufferRequest::new(
                        requests[i].size,
                        res.merged[i].first_use,
                        res.merged[i].last_use,
                    )
                })
                .collect();
            let sub_plan = GreedyPlanner.plan(&sub, align)?;
            for (k, &i) in unpinned.iter().enumerate() {
                offsets[i] = base + sub_plan.offsets[k];
            }
            arena_size = arena_size.max(base + sub_plan.arena_size);
        }

        // Aliases: honor an explicit pin (verify_plan rejects it if it
        // disagrees with the root), otherwise inherit the root's offset.
        for (i, &fo) in self.fixed_offsets.iter().enumerate() {
            let root = res.root_of[i];
            if root == i {
                continue;
            }
            if fo >= 0 {
                offsets[i] = fo as usize;
                arena_size = arena_size.max(fo as usize + requests[i].size);
            } else {
                offsets[i] = offsets[root];
            }
        }

        let plan = MemoryPlan { offsets, arena_size };
        // A corrupted or stale offline plan must fail loudly, not corrupt
        // memory: validate against lifetimes before accepting.
        super::verify_plan(requests, &plan)
            .map_err(|e| Error::PlanFailed(format!("offline plan rejected: {e}")))?;
        Ok(plan)
    }

    fn name(&self) -> &'static str {
        "offline"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::verify_plan;

    fn req(size: usize, first: usize, last: usize) -> BufferRequest {
        BufferRequest::new(size, first, last)
    }

    #[test]
    fn precomputed_plan_round_trips() {
        let reqs = vec![req(100, 0, 1), req(200, 1, 2), req(100, 2, 3)];
        let fixed = OfflinePlanner::precompute(&reqs, 16).unwrap();
        let planner = OfflinePlanner::new(fixed);
        let plan = planner.plan(&reqs, 16).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        // Offline should equal what greedy computed on the host.
        let greedy = GreedyPlanner.plan(&reqs, 16).unwrap();
        assert_eq!(plan.offsets, greedy.offsets);
    }

    #[test]
    fn corrupt_plan_rejected() {
        let reqs = vec![req(100, 0, 2), req(100, 1, 3)];
        // Both pinned to offset 0 while alive simultaneously: invalid.
        let planner = OfflinePlanner::new(vec![0, 0]);
        assert!(planner.plan(&reqs, 16).is_err());
    }

    #[test]
    fn wrong_length_rejected() {
        let reqs = vec![req(100, 0, 2)];
        let planner = OfflinePlanner::new(vec![0, 0]);
        assert!(planner.plan(&reqs, 16).is_err());
    }

    #[test]
    fn mixed_pinned_and_unpinned() {
        let reqs = vec![req(128, 0, 1), req(64, 1, 2), req(32, 2, 3)];
        // Pin the first at a deliberate offset; let the rest float.
        let planner = OfflinePlanner::new(vec![256, -1, -1]);
        let plan = planner.plan(&reqs, 16).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.offsets[0], 256);
        assert!(plan.arena_size >= 256 + 128);
    }

    #[test]
    fn unpinned_alias_follows_its_root() {
        // Root pinned, alias left to the planner: the alias must land on
        // the root's bytes, not in the floating region.
        let reqs = vec![req(128, 0, 1), req(128, 1, 3).with_alias(0), req(64, 2, 3)];
        let planner = OfflinePlanner::new(vec![64, -1, -1]);
        let plan = planner.plan(&reqs, 16).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.offsets[1], 64);
        // The floating buffer overlaps the alias's read window, so it
        // must sit clear of the root's (merged-lifetime) range.
        assert!(plan.offsets[2] >= 64 + 128 || plan.offsets[2] + 64 <= 64);
    }

    #[test]
    fn pinned_alias_must_match_root() {
        // A stale plan pinning an alias away from its root is rejected
        // rather than silently splitting the view from its storage.
        let reqs = vec![req(128, 0, 1), req(128, 1, 2).with_alias(0)];
        let planner = OfflinePlanner::new(vec![0, 256]);
        assert!(planner.plan(&reqs, 16).is_err());
        // Pinning it *at* the root is fine.
        let planner = OfflinePlanner::new(vec![0, 0]);
        let plan = planner.plan(&reqs, 16).unwrap();
        assert_eq!(plan.offsets, vec![0, 0]);
    }

    #[test]
    fn user_can_pin_to_memory_banks() {
        // The paper's motivation: pin big tensors to a specific bank
        // (here: offset 0) and keep small ones elsewhere.
        let reqs = vec![req(1024, 0, 3), req(64, 0, 3)];
        let planner = OfflinePlanner::new(vec![0, 1024]);
        let plan = planner.plan(&reqs, 16).unwrap();
        assert_eq!(plan.offsets, vec![0, 1024]);
        assert_eq!(plan.arena_size, 1088);
    }
}
