//! First-fit decreasing bin-packing planner (§4.4.2).
//!
//! "This approach consists of gathering a list of all temporary
//! allocations, including size and lifetime; sorting the list in
//! descending order by size; and placing each allocation in the first
//! sufficiently large gap, or at the end of the buffer if no such gap
//! exists." — the paper, verbatim. This is also how TFLite Micro's
//! `GreedyMemoryPlanner` works.

use super::{resolve_aliases, BufferRequest, MemoryPlan, MemoryPlanner};
use crate::error::Result;

/// The production memory planner: first-fit decreasing.
#[derive(Debug, Default, Clone, Copy)]
pub struct GreedyPlanner;

fn align_up(v: usize, align: usize) -> usize {
    (v + align - 1) & !(align - 1)
}

impl MemoryPlanner for GreedyPlanner {
    fn plan(&self, requests: &[BufferRequest], align: usize) -> Result<MemoryPlan> {
        assert!(align.is_power_of_two());
        // Only storage roots are packed; aliases inherit their root's
        // offset afterwards. Roots are packed against merged lifetimes
        // (their own plus every alias's), so the storage stays reserved
        // while any view of it is live.
        let res = resolve_aliases(requests)?;
        // Sort root indices by descending size; ties by earlier first-use
        // then index for determinism.
        let mut order: Vec<usize> =
            (0..requests.len()).filter(|&i| res.root_of[i] == i).collect();
        order.sort_by(|&a, &b| {
            requests[b]
                .size
                .cmp(&requests[a].size)
                .then(res.merged[a].first_use.cmp(&res.merged[b].first_use))
                .then(a.cmp(&b))
        });

        let mut offsets = vec![0usize; requests.len()];
        // Already-placed buffers, kept sorted by offset for gap search.
        let mut placed: Vec<usize> = Vec::with_capacity(order.len());
        let mut arena_size = 0usize;

        for &idx in &order {
            let req = &res.merged[idx];
            if req.size == 0 {
                offsets[idx] = 0;
                continue;
            }
            // Consider only placed buffers that overlap this one in time.
            // First fit: scan gaps between them in offset order.
            let mut candidate = 0usize;
            for &p in &placed {
                let pr = &res.merged[p];
                if !req.overlaps_in_time(pr) {
                    continue;
                }
                let p_off = offsets[p];
                if candidate + req.size <= p_off {
                    // Fits in the gap before this buffer.
                    break;
                }
                candidate = candidate.max(align_up(p_off + pr.size, align));
            }
            offsets[idx] = candidate;
            arena_size = arena_size.max(candidate + req.size);
            // Insert into `placed` keeping offset order.
            let pos = placed
                .binary_search_by(|&p| offsets[p].cmp(&candidate).then(std::cmp::Ordering::Less))
                .unwrap_or_else(|e| e);
            placed.insert(pos, idx);
        }

        // Aliases land exactly on their root's storage.
        for i in 0..requests.len() {
            let root = res.root_of[i];
            if root != i {
                offsets[i] = offsets[root];
            }
        }

        Ok(MemoryPlan { offsets, arena_size: align_up(arena_size, align) })
    }

    fn name(&self) -> &'static str {
        "greedy-ffd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{plan_lower_bound, verify_plan};
    use crate::testutil::{check, Cases};

    fn req(size: usize, first: usize, last: usize) -> BufferRequest {
        BufferRequest::new(size, first, last)
    }

    #[test]
    fn disjoint_lifetimes_share_space() {
        // Classic chain: A -> B -> C, each only alive across one op edge.
        let reqs = vec![req(100, 0, 1), req(100, 1, 2), req(100, 2, 3)];
        let plan = GreedyPlanner.plan(&reqs, 1).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        // A and C can share; B overlaps both. Optimal = 200.
        assert_eq!(plan.arena_size, 200);
    }

    #[test]
    fn fully_overlapping_buffers_stack() {
        let reqs = vec![req(64, 0, 9), req(32, 0, 9), req(16, 0, 9)];
        let plan = GreedyPlanner.plan(&reqs, 1).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.arena_size, 112);
    }

    #[test]
    fn gap_reuse_first_fit() {
        // Big buffer dies early, later small buffers should slot into the
        // freed space rather than extending the region.
        let reqs = vec![
            req(1000, 0, 1), // placed first (largest)
            req(400, 2, 3),
            req(300, 2, 3),
        ];
        let plan = GreedyPlanner.plan(&reqs, 1).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.arena_size, 1000, "later buffers must reuse the dead space");
    }

    #[test]
    fn respects_alignment() {
        let reqs = vec![req(3, 0, 5), req(5, 0, 5), req(7, 0, 5)];
        let plan = GreedyPlanner.plan(&reqs, 16).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        for &off in &plan.offsets {
            assert_eq!(off % 16, 0);
        }
    }

    #[test]
    fn empty_request_list() {
        let plan = GreedyPlanner.plan(&[], 16).unwrap();
        assert_eq!(plan.arena_size, 0);
        assert!(plan.offsets.is_empty());
    }

    #[test]
    fn paper_figure4_shape() {
        // A workload shaped like Figure 4: staggered lifetimes where naive
        // allocation wastes ~2x. Greedy must land well under the sum of
        // sizes and at (or near) the liveness lower bound.
        let reqs = vec![
            req(2048, 0, 2),
            req(1024, 1, 3),
            req(2048, 2, 4),
            req(512, 3, 5),
            req(1024, 4, 6),
            req(256, 5, 7),
        ];
        let total: usize = reqs.iter().map(|r| r.size).sum();
        let plan = GreedyPlanner.plan(&reqs, 1).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert!(plan.arena_size < total, "reuse must beat naive ({} vs {total})", plan.arena_size);
        let lb = plan_lower_bound(&reqs);
        assert!(
            plan.arena_size <= lb * 2,
            "greedy should be within 2x of lower bound ({} vs {lb})",
            plan.arena_size
        );
    }

    #[test]
    fn aliases_share_their_roots_offset() {
        // mid (1) is produced at t1; out (2) is an elided-reshape view of
        // it read through t3. A fat unrelated buffer (0) overlaps the
        // view's tail — it must not land on the root's bytes.
        let reqs = vec![
            req(512, 2, 3),
            req(256, 1, 2),
            req(256, 2, 3).with_alias(1),
        ];
        let plan = GreedyPlanner.plan(&reqs, 4).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.offsets[2], plan.offsets[1]);
        // Root + alias count once: 512 + 256, not 512 + 2*256.
        assert_eq!(plan.arena_size, 768);
    }

    #[test]
    fn alias_chain_planned_once() {
        // a <- b <- c chain with disjoint raw lifetimes: one storage
        // range serves all three, sized by the root.
        let reqs = vec![
            req(128, 0, 1),
            req(128, 1, 2).with_alias(0),
            req(64, 2, 5).with_alias(1),
        ];
        let plan = GreedyPlanner.plan(&reqs, 1).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.offsets, vec![0, 0, 0]);
        assert_eq!(plan.arena_size, 128);
    }

    #[test]
    fn malformed_alias_edges_fail_plan() {
        let reqs = vec![req(8, 0, 1).with_alias(9)];
        assert!(GreedyPlanner.plan(&reqs, 1).is_err());
    }

    #[test]
    fn property_aliased_plans_are_always_valid() {
        // Random lists where a suffix of requests aliases earlier ones
        // (always pointing backwards, like the rewriter's view edges —
        // acyclic by construction, sized within the target).
        check(Cases::n(300), |rng| {
            let n = 2 + rng.below(20);
            let horizon = 1 + rng.below(12);
            let mut reqs: Vec<BufferRequest> = Vec::with_capacity(n);
            for i in 0..n {
                let first = rng.below(horizon);
                let last = first + rng.below(horizon - first.min(horizon - 1));
                let mut r = req(1 + rng.below(2048), first, last);
                if i > 0 && rng.below(3) == 0 {
                    let target = rng.below(i);
                    if reqs[target].size >= r.size {
                        r = r.with_alias(target);
                    }
                }
                reqs.push(r);
            }
            let align = 1usize << rng.below(6);
            let plan =
                GreedyPlanner.plan(&reqs, align).map_err(|e| format!("plan failed: {e}"))?;
            verify_plan(&reqs, &plan).map_err(|e| format!("invalid plan: {e}"))?;
            Ok(())
        });
    }

    #[test]
    fn property_plans_are_always_valid_and_bounded() {
        check(Cases::n(300), |rng| {
            let n = 1 + rng.below(24);
            let horizon = 1 + rng.below(16);
            let reqs: Vec<BufferRequest> = (0..n)
                .map(|_| {
                    let first = rng.below(horizon);
                    let last = first + rng.below(horizon - first.min(horizon - 1));
                    req(rng.below(4096), first, last)
                })
                .collect();
            let align = 1usize << rng.below(6);
            let plan = GreedyPlanner
                .plan(&reqs, align)
                .map_err(|e| format!("plan failed: {e}"))?;
            verify_plan(&reqs, &plan).map_err(|e| format!("invalid plan: {e}"))?;
            // Never worse than linear (sum of aligned sizes).
            let naive: usize = reqs.iter().map(|r| (r.size + align - 1) & !(align - 1)).sum();
            if plan.arena_size > naive + align {
                return Err(format!("greedy ({}) worse than naive ({naive})", plan.arena_size));
            }
            Ok(())
        });
    }
}
