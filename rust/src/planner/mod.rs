//! Memory planning for intermediate tensors (§4.4.2, Figure 4).
//!
//! An intermediate tensor only needs storage from just before the op that
//! produces it until the last op that reads it; buffers whose lifetimes do
//! not overlap can share arena space. Sizing the shared region is a
//! bin-packing instance (Martello 1990); like the paper we use the
//! **first-fit decreasing** heuristic (Garey et al. 1972), which "usually
//! provides reasonable solutions".
//!
//! Planners provided:
//!
//! * [`GreedyPlanner`] — first-fit decreasing; the paper's production
//!   planner (and TFLite Micro's `GreedyMemoryPlanner`).
//! * [`LinearPlanner`] — no reuse at all; every buffer gets distinct
//!   space. This is Figure 4a, kept as the ablation baseline.
//! * [`OfflinePlanner`] — offsets fixed ahead of time on a host and
//!   carried in model metadata (§4.4.2 "offline-planned tensor
//!   allocation"); the runtime validates and applies them with near-zero
//!   planning work on-device.
//!
//! All planners consume dtype-erased [`BufferRequest`]s (size + lifetime)
//! and produce offsets into a single contiguous region, so they are
//! reusable for scratch buffers as well as tensors.

mod greedy;
mod lifetimes;
mod linear;
mod offline;

pub use greedy::GreedyPlanner;
pub use lifetimes::{analyze_lifetimes, LifetimeInfo};
pub use linear::LinearPlanner;
pub use offline::OfflinePlanner;

use crate::error::{Error, Result};

/// One buffer the planner must place.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufferRequest {
    /// Size in bytes (already padded/aligned by the caller if needed).
    pub size: usize,
    /// Index of the first op (in execution order) that needs the buffer
    /// live. The producing op's index for activations.
    pub first_use: usize,
    /// Index of the last op that needs the buffer live (inclusive).
    pub last_use: usize,
}

impl BufferRequest {
    /// True if two requests are live at the same time.
    pub fn overlaps_in_time(&self, other: &BufferRequest) -> bool {
        self.first_use <= other.last_use && other.first_use <= self.last_use
    }
}

/// The planner's output: one offset per request, plus the region size.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoryPlan {
    /// Byte offset of each request within the planned region, in the same
    /// order as the input requests.
    pub offsets: Vec<usize>,
    /// Total bytes the region needs.
    pub arena_size: usize,
}

/// A memory-planning strategy.
pub trait MemoryPlanner {
    /// Compute a placement for `requests`. Offsets are aligned to `align`.
    fn plan(&self, requests: &[BufferRequest], align: usize) -> Result<MemoryPlan>;

    /// Planner name for benches and logs.
    fn name(&self) -> &'static str;
}

/// Verify a plan: every pair of time-overlapping buffers must occupy
/// disjoint byte ranges, and every buffer must fit in `arena_size`.
/// Used by tests, the property suite, and offline-plan validation.
pub fn verify_plan(requests: &[BufferRequest], plan: &MemoryPlan) -> Result<()> {
    if plan.offsets.len() != requests.len() {
        return Err(Error::PlanFailed(format!(
            "plan has {} offsets for {} requests",
            plan.offsets.len(),
            requests.len()
        )));
    }
    for (i, (r, &off)) in requests.iter().zip(&plan.offsets).enumerate() {
        if off + r.size > plan.arena_size {
            return Err(Error::PlanFailed(format!(
                "buffer {i} ({} bytes at {off}) exceeds region size {}",
                r.size, plan.arena_size
            )));
        }
        if r.first_use > r.last_use {
            return Err(Error::PlanFailed(format!(
                "buffer {i} has inverted lifetime {}..{}",
                r.first_use, r.last_use
            )));
        }
    }
    for i in 0..requests.len() {
        for j in (i + 1)..requests.len() {
            let (a, b) = (&requests[i], &requests[j]);
            if a.size == 0 || b.size == 0 {
                continue;
            }
            if a.overlaps_in_time(b) {
                let (ao, bo) = (plan.offsets[i], plan.offsets[j]);
                let space_disjoint = ao + a.size <= bo || bo + b.size <= ao;
                if !space_disjoint {
                    return Err(Error::PlanFailed(format!(
                        "buffers {i} (t{}..{}, {}B @ {ao}) and {j} (t{}..{}, {}B @ {bo}) \
                         overlap in both time and space",
                        a.first_use, a.last_use, a.size, b.first_use, b.last_use, b.size
                    )));
                }
            }
        }
    }
    Ok(())
}

/// Lower bound on any valid plan's size: the max over op timesteps of the
/// sum of sizes of buffers live at that step. Used to gauge plan quality.
pub fn plan_lower_bound(requests: &[BufferRequest]) -> usize {
    let max_t = requests.iter().map(|r| r.last_use).max().unwrap_or(0);
    let mut best = 0usize;
    for t in 0..=max_t {
        let live: usize = requests
            .iter()
            .filter(|r| r.first_use <= t && t <= r.last_use)
            .map(|r| r.size)
            .sum();
        best = best.max(live);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_predicate() {
        let a = BufferRequest { size: 1, first_use: 0, last_use: 3 };
        let b = BufferRequest { size: 1, first_use: 3, last_use: 5 };
        let c = BufferRequest { size: 1, first_use: 4, last_use: 5 };
        assert!(a.overlaps_in_time(&b)); // share step 3
        assert!(!a.overlaps_in_time(&c));
        assert!(b.overlaps_in_time(&c));
    }

    #[test]
    fn verify_rejects_bad_plans() {
        let reqs = vec![
            BufferRequest { size: 100, first_use: 0, last_use: 2 },
            BufferRequest { size: 100, first_use: 1, last_use: 3 },
        ];
        // Overlapping placement of time-overlapping buffers.
        let bad = MemoryPlan { offsets: vec![0, 50], arena_size: 200 };
        assert!(verify_plan(&reqs, &bad).is_err());
        // Buffer exceeding region.
        let bad = MemoryPlan { offsets: vec![0, 150], arena_size: 200 };
        assert!(verify_plan(&reqs, &bad).is_err());
        // Good plan.
        let good = MemoryPlan { offsets: vec![0, 100], arena_size: 200 };
        assert!(verify_plan(&reqs, &good).is_ok());
    }

    #[test]
    fn lower_bound_is_peak_liveness() {
        let reqs = vec![
            BufferRequest { size: 100, first_use: 0, last_use: 1 },
            BufferRequest { size: 50, first_use: 1, last_use: 2 },
            BufferRequest { size: 60, first_use: 2, last_use: 3 },
        ];
        // Peak at t=1: 100 + 50.
        assert_eq!(plan_lower_bound(&reqs), 150);
    }

    #[test]
    fn zero_sized_requests_never_conflict() {
        let reqs = vec![
            BufferRequest { size: 0, first_use: 0, last_use: 5 },
            BufferRequest { size: 10, first_use: 0, last_use: 5 },
        ];
        let plan = MemoryPlan { offsets: vec![0, 0], arena_size: 10 };
        assert!(verify_plan(&reqs, &plan).is_ok());
    }
}
