//! Memory planning for intermediate tensors (§4.4.2, Figure 4).
//!
//! An intermediate tensor only needs storage from just before the op that
//! produces it until the last op that reads it; buffers whose lifetimes do
//! not overlap can share arena space. Sizing the shared region is a
//! bin-packing instance (Martello 1990); like the paper we use the
//! **first-fit decreasing** heuristic (Garey et al. 1972), which "usually
//! provides reasonable solutions".
//!
//! Planners provided:
//!
//! * [`GreedyPlanner`] — first-fit decreasing; the paper's production
//!   planner (and TFLite Micro's `GreedyMemoryPlanner`).
//! * [`LinearPlanner`] — no reuse at all; every buffer gets distinct
//!   space. This is Figure 4a, kept as the ablation baseline.
//! * [`OfflinePlanner`] — offsets fixed ahead of time on a host and
//!   carried in model metadata (§4.4.2 "offline-planned tensor
//!   allocation"); the runtime validates and applies them with near-zero
//!   planning work on-device.
//!
//! All planners consume dtype-erased [`BufferRequest`]s (size + lifetime)
//! and produce offsets into a single contiguous region, so they are
//! reusable for scratch buffers as well as tensors.

mod greedy;
mod lifetimes;
mod linear;
mod offline;

pub use greedy::GreedyPlanner;
pub use lifetimes::{analyze_lifetimes, LifetimeInfo};
pub use linear::LinearPlanner;
pub use offline::OfflinePlanner;

use crate::error::{Error, Result};

/// One buffer the planner must place.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufferRequest {
    /// Size in bytes (already padded/aligned by the caller if needed).
    pub size: usize,
    /// Index of the first op (in execution order) that needs the buffer
    /// live. The producing op's index for activations.
    pub first_use: usize,
    /// Index of the last op that needs the buffer live (inclusive).
    pub last_use: usize,
    /// Index (into the same request list) of the request whose storage
    /// this one aliases. An aliased pair is a *view* relationship (the
    /// graph rewriter's elided reshapes): the two requests must receive
    /// the same offset, and the storage root's lifetime is extended to
    /// cover every alias. `None` for ordinary requests.
    pub alias_of: Option<usize>,
}

impl BufferRequest {
    /// A plain (non-alias) request.
    pub fn new(size: usize, first_use: usize, last_use: usize) -> Self {
        BufferRequest { size, first_use, last_use, alias_of: None }
    }

    /// Mark this request as an alias of `root`'s storage.
    pub fn with_alias(mut self, root: usize) -> Self {
        self.alias_of = Some(root);
        self
    }

    /// True if two requests are live at the same time.
    pub fn overlaps_in_time(&self, other: &BufferRequest) -> bool {
        self.first_use <= other.last_use && other.first_use <= self.last_use
    }
}

/// Alias edges collapsed to storage roots (see [`resolve_aliases`]).
pub(crate) struct AliasResolution {
    /// For each request, the index of its storage root — itself when the
    /// request is not an alias. Chains (alias of an alias) resolve to the
    /// final non-alias request.
    pub root_of: Vec<usize>,
    /// Copy of the requests with every root's lifetime widened to the
    /// union of its own and all of its aliases' lifetimes. Placement and
    /// conflict checks must use these lifetimes: the root's storage has
    /// to stay reserved while any view of it is read.
    pub merged: Vec<BufferRequest>,
}

/// Resolve alias chains to storage roots and merge lifetimes onto them.
///
/// Rejected (the request list is malformed): an `alias_of` index out of
/// range, a cyclic alias chain, and an alias larger than its storage
/// root (a view cannot read bytes its source does not own).
pub(crate) fn resolve_aliases(requests: &[BufferRequest]) -> Result<AliasResolution> {
    let n = requests.len();
    let mut root_of = vec![0usize; n];
    for i in 0..n {
        let mut cur = i;
        let mut steps = 0usize;
        while let Some(next) = requests[cur].alias_of {
            if next >= n {
                return Err(Error::PlanFailed(format!(
                    "request {cur} aliases out-of-range request {next} ({n} requests)"
                )));
            }
            steps += 1;
            if steps > n {
                return Err(Error::PlanFailed(format!(
                    "alias chain starting at request {i} contains a cycle"
                )));
            }
            cur = next;
        }
        root_of[i] = cur;
    }
    let mut merged: Vec<BufferRequest> = requests.to_vec();
    for i in 0..n {
        let r = root_of[i];
        if r == i {
            continue;
        }
        if requests[i].size > requests[r].size {
            return Err(Error::PlanFailed(format!(
                "alias request {i} ({} bytes) larger than its storage root {r} ({} bytes)",
                requests[i].size, requests[r].size
            )));
        }
        merged[r].first_use = merged[r].first_use.min(requests[i].first_use);
        merged[r].last_use = merged[r].last_use.max(requests[i].last_use);
    }
    Ok(AliasResolution { root_of, merged })
}

/// The planner's output: one offset per request, plus the region size.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoryPlan {
    /// Byte offset of each request within the planned region, in the same
    /// order as the input requests.
    pub offsets: Vec<usize>,
    /// Total bytes the region needs.
    pub arena_size: usize,
}

/// A memory-planning strategy.
pub trait MemoryPlanner {
    /// Compute a placement for `requests`. Offsets are aligned to `align`.
    fn plan(&self, requests: &[BufferRequest], align: usize) -> Result<MemoryPlan>;

    /// Planner name for benches and logs.
    fn name(&self) -> &'static str;
}

/// Verify a plan: every pair of time-overlapping storage roots must
/// occupy disjoint byte ranges, every buffer must fit in `arena_size`,
/// and every alias must sit exactly at its storage root's offset. Roots
/// are checked against *merged* lifetimes (their own plus all aliases'),
/// so a plan that reuses a root's bytes while only a view of it is still
/// live — the "alias outlives its source" hazard — is rejected. Alias
/// edges that do not resolve (out of range, cyclic, alias larger than
/// its root) are rejected outright. Used by tests, the property suite,
/// and offline-plan validation.
pub fn verify_plan(requests: &[BufferRequest], plan: &MemoryPlan) -> Result<()> {
    if plan.offsets.len() != requests.len() {
        return Err(Error::PlanFailed(format!(
            "plan has {} offsets for {} requests",
            plan.offsets.len(),
            requests.len()
        )));
    }
    let res = resolve_aliases(requests)?;
    for (i, (r, &off)) in requests.iter().zip(&plan.offsets).enumerate() {
        if off + r.size > plan.arena_size {
            return Err(Error::PlanFailed(format!(
                "buffer {i} ({} bytes at {off}) exceeds region size {}",
                r.size, plan.arena_size
            )));
        }
        if r.first_use > r.last_use {
            return Err(Error::PlanFailed(format!(
                "buffer {i} has inverted lifetime {}..{}",
                r.first_use, r.last_use
            )));
        }
        let root = res.root_of[i];
        if root != i && plan.offsets[root] != off {
            return Err(Error::PlanFailed(format!(
                "alias buffer {i} placed at {off} but its storage root {root} is at {}",
                plan.offsets[root]
            )));
        }
    }
    // Spatial exclusivity over storage roots only: aliases share their
    // root's range by construction (checked above), so an alias/root or
    // alias/alias overlap within one chain is legal — that sharing is the
    // point. Distinct roots conflict on their merged lifetimes.
    let roots: Vec<usize> = (0..requests.len()).filter(|&i| res.root_of[i] == i).collect();
    for (k, &i) in roots.iter().enumerate() {
        for &j in roots.iter().skip(k + 1) {
            let (a, b) = (&res.merged[i], &res.merged[j]);
            if a.size == 0 || b.size == 0 {
                continue;
            }
            if a.overlaps_in_time(b) {
                let (ao, bo) = (plan.offsets[i], plan.offsets[j]);
                let space_disjoint = ao + a.size <= bo || bo + b.size <= ao;
                if !space_disjoint {
                    return Err(Error::PlanFailed(format!(
                        "buffers {i} (t{}..{}, {}B @ {ao}) and {j} (t{}..{}, {}B @ {bo}) \
                         overlap in both time and space",
                        a.first_use, a.last_use, a.size, b.first_use, b.last_use, b.size
                    )));
                }
            }
        }
    }
    Ok(())
}

/// Lower bound on any valid plan's size: the max over op timesteps of the
/// sum of sizes of buffers live at that step. Aliases contribute no bytes
/// of their own (they share their root's storage); the root counts once,
/// over its merged lifetime. Used to gauge plan quality.
pub fn plan_lower_bound(requests: &[BufferRequest]) -> usize {
    let reqs: Vec<BufferRequest> = match resolve_aliases(requests) {
        Ok(res) => {
            (0..requests.len()).filter(|&i| res.root_of[i] == i).map(|i| res.merged[i]).collect()
        }
        // Unresolvable alias edges: every planner will reject the list,
        // but a conservative bound over the raw requests is still a
        // lower bound.
        Err(_) => requests.to_vec(),
    };
    let max_t = reqs.iter().map(|r| r.last_use).max().unwrap_or(0);
    let mut best = 0usize;
    for t in 0..=max_t {
        let live: usize =
            reqs.iter().filter(|r| r.first_use <= t && t <= r.last_use).map(|r| r.size).sum();
        best = best.max(live);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_predicate() {
        let a = BufferRequest::new(1, 0, 3);
        let b = BufferRequest::new(1, 3, 5);
        let c = BufferRequest::new(1, 4, 5);
        assert!(a.overlaps_in_time(&b)); // share step 3
        assert!(!a.overlaps_in_time(&c));
        assert!(b.overlaps_in_time(&c));
    }

    #[test]
    fn verify_rejects_bad_plans() {
        let reqs = vec![BufferRequest::new(100, 0, 2), BufferRequest::new(100, 1, 3)];
        // Overlapping placement of time-overlapping buffers.
        let bad = MemoryPlan { offsets: vec![0, 50], arena_size: 200 };
        assert!(verify_plan(&reqs, &bad).is_err());
        // Buffer exceeding region.
        let bad = MemoryPlan { offsets: vec![0, 150], arena_size: 200 };
        assert!(verify_plan(&reqs, &bad).is_err());
        // Good plan.
        let good = MemoryPlan { offsets: vec![0, 100], arena_size: 200 };
        assert!(verify_plan(&reqs, &good).is_ok());
    }

    #[test]
    fn lower_bound_is_peak_liveness() {
        let reqs = vec![
            BufferRequest::new(100, 0, 1),
            BufferRequest::new(50, 1, 2),
            BufferRequest::new(60, 2, 3),
        ];
        // Peak at t=1: 100 + 50.
        assert_eq!(plan_lower_bound(&reqs), 150);
    }

    #[test]
    fn zero_sized_requests_never_conflict() {
        let reqs = vec![BufferRequest::new(0, 0, 5), BufferRequest::new(10, 0, 5)];
        let plan = MemoryPlan { offsets: vec![0, 0], arena_size: 10 };
        assert!(verify_plan(&reqs, &plan).is_ok());
    }

    #[test]
    fn alias_chains_resolve_to_final_root() {
        // 2 -> 1 -> 0: an alias of an alias lands on the ultimate root,
        // and the root's merged lifetime spans every link in the chain.
        let reqs = vec![
            BufferRequest::new(64, 0, 1),
            BufferRequest::new(64, 2, 3).with_alias(0),
            BufferRequest::new(32, 4, 6).with_alias(1),
        ];
        let res = resolve_aliases(&reqs).unwrap();
        assert_eq!(res.root_of, vec![0, 0, 0]);
        assert_eq!((res.merged[0].first_use, res.merged[0].last_use), (0, 6));
        // A shared-offset plan passes; an alias elsewhere fails.
        let good = MemoryPlan { offsets: vec![0, 0, 0], arena_size: 64 };
        assert!(verify_plan(&reqs, &good).is_ok());
        let bad = MemoryPlan { offsets: vec![0, 0, 64], arena_size: 128 };
        assert!(verify_plan(&reqs, &bad).is_err());
    }

    #[test]
    fn malformed_alias_edges_rejected() {
        // Out-of-range target.
        let reqs = vec![BufferRequest::new(8, 0, 1).with_alias(5)];
        assert!(resolve_aliases(&reqs).is_err());
        // Cycle.
        let reqs = vec![
            BufferRequest::new(8, 0, 1).with_alias(1),
            BufferRequest::new(8, 0, 1).with_alias(0),
        ];
        assert!(resolve_aliases(&reqs).is_err());
        // Alias larger than its storage root.
        let reqs = vec![BufferRequest::new(8, 0, 1), BufferRequest::new(16, 1, 2).with_alias(0)];
        assert!(resolve_aliases(&reqs).is_err());
        // All three also fail plan verification (not just resolution).
        let plan = MemoryPlan { offsets: vec![0, 0], arena_size: 16 };
        assert!(verify_plan(&reqs, &plan).is_err());
    }

    #[test]
    fn alias_outliving_source_blocks_root_reuse() {
        // Root dies at t=1 but its alias is read until t=4. A plan that
        // recycles the root's bytes for another buffer at t=3 would be
        // legal on raw lifetimes — merged lifetimes reject it.
        let reqs = vec![
            BufferRequest::new(32, 0, 1),
            BufferRequest::new(32, 2, 4).with_alias(0),
            BufferRequest::new(32, 3, 5),
        ];
        let stale = MemoryPlan { offsets: vec![0, 0, 0], arena_size: 32 };
        assert!(verify_plan(&reqs, &stale).is_err());
        let safe = MemoryPlan { offsets: vec![0, 0, 32], arena_size: 64 };
        assert!(verify_plan(&reqs, &safe).is_ok());
        // The lower bound counts the root once, over the union lifetime:
        // at t=3 both the aliased chain and buffer 2 are live.
        assert_eq!(plan_lower_bound(&reqs), 64);
    }
}
