//! Tensor lifetime analysis over the sorted operator list (§4.4.2).
//!
//! Because the operator list is topologically sorted and shapes are
//! static, lifetimes fall out of a single pass: an activation tensor must
//! exist from the op that produces it through the last op that reads it.
//! Graph inputs are live from "before op 0" (step 0); graph outputs stay
//! live through the final op so the application can read them after
//! `invoke` returns.

use super::BufferRequest;
use crate::schema::Model;

/// Lifetime analysis result for one model.
#[derive(Debug, Clone)]
pub struct LifetimeInfo {
    /// Indices (into `model.tensors()`) of the arena-resident,
    /// non-variable tensors that need planning, in request order.
    pub tensor_indices: Vec<usize>,
    /// One request per entry of `tensor_indices`.
    pub requests: Vec<BufferRequest>,
}

/// Compute buffer requests for every plannable tensor in `model`.
///
/// Variable tensors (persistent state) and constants are excluded — the
/// interpreter gives variables interpreter-lifetime (tail) storage and
/// constants live in the serialized model.
pub fn analyze_lifetimes(model: &Model) -> LifetimeInfo {
    let n_tensors = model.tensors().len();
    let n_ops = model.operators().len();
    let mut first = vec![usize::MAX; n_tensors];
    let mut last = vec![0usize; n_tensors];

    for &t in model.inputs() {
        first[t as usize] = 0;
    }
    for (op_idx, op) in model.operators().iter().enumerate() {
        for &t in op.inputs.iter().chain(op.outputs.iter()) {
            if t == -1 {
                continue;
            }
            let ti = t as usize;
            first[ti] = first[ti].min(op_idx);
            last[ti] = last[ti].max(op_idx);
        }
    }
    // Outputs must survive past the last op.
    let final_step = n_ops.saturating_sub(1);
    for &t in model.outputs() {
        last[t as usize] = last[t as usize].max(final_step);
    }

    let mut tensor_indices = Vec::new();
    let mut requests = Vec::new();
    for (ti, meta) in model.tensors().iter().enumerate() {
        if !meta.needs_arena() || meta.is_variable {
            continue;
        }
        if first[ti] == usize::MAX {
            // Dead tensor (never referenced): still give it zero-cost
            // placement so indexing stays simple.
            first[ti] = 0;
        }
        tensor_indices.push(ti);
        requests.push(BufferRequest {
            size: meta.num_bytes(),
            first_use: first[ti],
            last_use: last[ti].max(first[ti]),
        });
    }
    LifetimeInfo { tensor_indices, requests }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::{BuiltinOp, Model, ModelBuilder};
    use crate::tensor::DType;

    /// in -> relu -> mid -> relu -> out, with a constant weight on the side.
    fn chain_model() -> Model {
        let mut b = ModelBuilder::new("chain");
        let t_in = b.add_tensor("in", DType::F32, &[4], None);
        let t_mid = b.add_tensor("mid", DType::F32, &[4], None);
        let t_out = b.add_tensor("out", DType::F32, &[4], None);
        let buf = b.add_buffer(&[0u8; 16]);
        let _t_w = b.add_tensor("w", DType::F32, &[4], Some(buf));
        b.add_op(BuiltinOp::Relu, &[t_in], &[t_mid], vec![]);
        b.add_op(BuiltinOp::Relu, &[t_mid], &[t_out], vec![]);
        b.set_io(&[t_in], &[t_out]);
        Model::from_bytes(&b.finish()).unwrap()
    }

    #[test]
    fn chain_lifetimes() {
        let m = chain_model();
        let info = analyze_lifetimes(&m);
        // Constants are excluded: only in, mid, out.
        assert_eq!(info.tensor_indices, vec![0, 1, 2]);
        let [r_in, r_mid, r_out] = info.requests[..] else { panic!() };
        assert_eq!((r_in.first_use, r_in.last_use), (0, 0));
        assert_eq!((r_mid.first_use, r_mid.last_use), (0, 1));
        assert_eq!((r_out.first_use, r_out.last_use), (1, 1));
    }

    #[test]
    fn outputs_live_to_end() {
        // Output produced early must stay live through the last op.
        let mut b = ModelBuilder::new("early-out");
        let t_in = b.add_tensor("in", DType::F32, &[4], None);
        let t_early = b.add_tensor("early", DType::F32, &[4], None);
        let t_late = b.add_tensor("late", DType::F32, &[4], None);
        b.add_op(BuiltinOp::Relu, &[t_in], &[t_early], vec![]);
        b.add_op(BuiltinOp::Relu, &[t_in], &[t_late], vec![]);
        b.set_io(&[t_in], &[t_early, t_late]);
        let m = Model::from_bytes(&b.finish()).unwrap();
        let info = analyze_lifetimes(&m);
        let early = &info.requests[1];
        assert_eq!(early.last_use, 1, "graph output must survive to the final op");
    }

    #[test]
    fn variables_excluded() {
        let mut b = ModelBuilder::new("var");
        let t_in = b.add_tensor("in", DType::F32, &[4], None);
        let t_state = b.add_tensor("state", DType::F32, &[4], None);
        b.set_variable(t_state);
        let t_out = b.add_tensor("out", DType::F32, &[4], None);
        b.add_op(BuiltinOp::Add, &[t_in, t_state], &[t_out], crate::schema::writer::elementwise_options(Default::default()));
        b.set_io(&[t_in], &[t_out]);
        let m = Model::from_bytes(&b.finish()).unwrap();
        let info = analyze_lifetimes(&m);
        assert!(!info.tensor_indices.contains(&(t_state as usize)));
    }

    #[test]
    fn sizes_match_tensor_bytes() {
        let m = chain_model();
        let info = analyze_lifetimes(&m);
        for (&ti, r) in info.tensor_indices.iter().zip(&info.requests) {
            assert_eq!(r.size, m.tensors()[ti].num_bytes());
        }
    }
}
