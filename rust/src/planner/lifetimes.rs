//! Tensor lifetime analysis over the sorted operator list (§4.4.2).
//!
//! Because the operator list is topologically sorted and shapes are
//! static, lifetimes fall out of a single pass: an activation tensor must
//! exist from the op that produces it through the last op that reads it.
//! Graph inputs are live from "before op 0" (step 0); graph outputs stay
//! live through the final op so the application can read them after
//! `invoke` returns.
//!
//! Rewritten models may carry planner alias metadata (the graph
//! rewriter's elided reshapes, [`crate::rewriter`]): pairs of tensors
//! that must share one arena range. Those edges are translated into
//! [`BufferRequest::alias_of`] links here so every planner sees them.

use super::BufferRequest;
use crate::error::{Error, Result};
use crate::schema::Model;

/// Lifetime analysis result for one model.
#[derive(Debug, Clone)]
pub struct LifetimeInfo {
    /// Indices (into `model.tensors()`) of the arena-resident,
    /// non-variable tensors that need planning, in request order.
    pub tensor_indices: Vec<usize>,
    /// One request per entry of `tensor_indices`.
    pub requests: Vec<BufferRequest>,
}

/// Compute buffer requests for every plannable tensor in `model`.
///
/// Variable tensors (persistent state) and constants are excluded — the
/// interpreter gives variables interpreter-lifetime (tail) storage and
/// constants live in the serialized model.
///
/// Fails only when the model's rewrite-alias metadata references a tensor
/// the planner does not manage (out of range, constant, or variable) —
/// such a model cannot be planned soundly.
pub fn analyze_lifetimes(model: &Model) -> Result<LifetimeInfo> {
    let n_tensors = model.tensors().len();
    let n_ops = model.operators().len();
    let mut first = vec![usize::MAX; n_tensors];
    let mut last = vec![0usize; n_tensors];

    for &t in model.inputs() {
        first[t as usize] = 0;
    }
    for (op_idx, op) in model.operators().iter().enumerate() {
        for &t in op.inputs.iter().chain(op.outputs.iter()) {
            if t == -1 {
                continue;
            }
            let ti = t as usize;
            first[ti] = first[ti].min(op_idx);
            last[ti] = last[ti].max(op_idx);
        }
    }
    // Outputs must survive past the last op.
    let final_step = n_ops.saturating_sub(1);
    for &t in model.outputs() {
        last[t as usize] = last[t as usize].max(final_step);
    }

    let mut tensor_indices = Vec::new();
    let mut requests = Vec::new();
    for (ti, meta) in model.tensors().iter().enumerate() {
        if !meta.needs_arena() || meta.is_variable {
            continue;
        }
        if first[ti] == usize::MAX {
            // Dead tensor (never referenced): still give it zero-cost
            // placement so indexing stays simple.
            first[ti] = 0;
        }
        tensor_indices.push(ti);
        requests.push(BufferRequest::new(meta.num_bytes(), first[ti], last[ti].max(first[ti])));
    }

    // Translate rewrite-alias metadata (tensor index -> tensor index)
    // into request-index alias edges.
    if let Some(alias_pairs) = model.rewrite_aliases() {
        let mut req_of = vec![usize::MAX; n_tensors];
        for (k, &ti) in tensor_indices.iter().enumerate() {
            req_of[ti] = k;
        }
        for (alias, src) in alias_pairs {
            let (a, s) = (alias as usize, src as usize);
            if a >= n_tensors
                || s >= n_tensors
                || req_of[a] == usize::MAX
                || req_of[s] == usize::MAX
            {
                return Err(Error::MalformedModel(format!(
                    "rewrite alias ({alias} -> {src}) references a tensor the planner \
                     does not manage"
                )));
            }
            requests[req_of[a]].alias_of = Some(req_of[s]);
        }
    }

    Ok(LifetimeInfo { tensor_indices, requests })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::{BuiltinOp, Model, ModelBuilder};
    use crate::tensor::DType;

    /// in -> relu -> mid -> relu -> out, with a constant weight on the side.
    fn chain_model() -> Model {
        let mut b = ModelBuilder::new("chain");
        let t_in = b.add_tensor("in", DType::F32, &[4], None);
        let t_mid = b.add_tensor("mid", DType::F32, &[4], None);
        let t_out = b.add_tensor("out", DType::F32, &[4], None);
        let buf = b.add_buffer(&[0u8; 16]);
        let _t_w = b.add_tensor("w", DType::F32, &[4], Some(buf));
        b.add_op(BuiltinOp::Relu, &[t_in], &[t_mid], vec![]);
        b.add_op(BuiltinOp::Relu, &[t_mid], &[t_out], vec![]);
        b.set_io(&[t_in], &[t_out]);
        Model::from_bytes(&b.finish()).unwrap()
    }

    #[test]
    fn chain_lifetimes() {
        let m = chain_model();
        let info = analyze_lifetimes(&m).unwrap();
        // Constants are excluded: only in, mid, out.
        assert_eq!(info.tensor_indices, vec![0, 1, 2]);
        let [r_in, r_mid, r_out] = info.requests[..] else { panic!() };
        assert_eq!((r_in.first_use, r_in.last_use), (0, 0));
        assert_eq!((r_mid.first_use, r_mid.last_use), (0, 1));
        assert_eq!((r_out.first_use, r_out.last_use), (1, 1));
    }

    #[test]
    fn outputs_live_to_end() {
        // Output produced early must stay live through the last op.
        let mut b = ModelBuilder::new("early-out");
        let t_in = b.add_tensor("in", DType::F32, &[4], None);
        let t_early = b.add_tensor("early", DType::F32, &[4], None);
        let t_late = b.add_tensor("late", DType::F32, &[4], None);
        b.add_op(BuiltinOp::Relu, &[t_in], &[t_early], vec![]);
        b.add_op(BuiltinOp::Relu, &[t_in], &[t_late], vec![]);
        b.set_io(&[t_in], &[t_early, t_late]);
        let m = Model::from_bytes(&b.finish()).unwrap();
        let info = analyze_lifetimes(&m).unwrap();
        let early = &info.requests[1];
        assert_eq!(early.last_use, 1, "graph output must survive to the final op");
    }

    #[test]
    fn variables_excluded() {
        let mut b = ModelBuilder::new("var");
        let t_in = b.add_tensor("in", DType::F32, &[4], None);
        let t_state = b.add_tensor("state", DType::F32, &[4], None);
        b.set_variable(t_state);
        let t_out = b.add_tensor("out", DType::F32, &[4], None);
        b.add_op(BuiltinOp::Add, &[t_in, t_state], &[t_out], crate::schema::writer::elementwise_options(Default::default()));
        b.set_io(&[t_in], &[t_out]);
        let m = Model::from_bytes(&b.finish()).unwrap();
        let info = analyze_lifetimes(&m).unwrap();
        assert!(!info.tensor_indices.contains(&(t_state as usize)));
    }

    #[test]
    fn sizes_match_tensor_bytes() {
        let m = chain_model();
        let info = analyze_lifetimes(&m).unwrap();
        for (&ti, r) in info.tensor_indices.iter().zip(&info.requests) {
            assert_eq!(r.size, m.tensors()[ti].num_bytes());
        }
    }

    #[test]
    fn rewrite_alias_metadata_becomes_request_edges() {
        // Same chain, plus alias metadata marking `out` a view of `mid`
        // (what the rewriter emits for an elided reshape).
        let mut b = ModelBuilder::new("chain-alias");
        let t_in = b.add_tensor("in", DType::F32, &[4], None);
        let t_mid = b.add_tensor("mid", DType::F32, &[4], None);
        let t_out = b.add_tensor("out", DType::F32, &[4], None);
        b.add_op(BuiltinOp::Relu, &[t_in], &[t_mid], vec![]);
        b.add_op(BuiltinOp::Relu, &[t_mid], &[t_out], vec![]);
        b.set_io(&[t_in], &[t_out]);
        let mut blob = Vec::new();
        blob.extend_from_slice(&(t_out as u32).to_le_bytes());
        blob.extend_from_slice(&(t_mid as u32).to_le_bytes());
        b.add_metadata(crate::schema::REWRITE_ALIAS_KEY, &blob);
        let m = Model::from_bytes(&b.finish()).unwrap();
        let info = analyze_lifetimes(&m).unwrap();
        assert_eq!(info.requests[2].alias_of, Some(1));
        assert_eq!(info.requests[0].alias_of, None);
    }

    #[test]
    fn alias_to_unplannable_tensor_rejected() {
        // Alias metadata naming a constant tensor: the planner never
        // places constants, so the edge cannot be honored.
        let mut b = ModelBuilder::new("bad-alias");
        let t_in = b.add_tensor("in", DType::F32, &[4], None);
        let t_out = b.add_tensor("out", DType::F32, &[4], None);
        let buf = b.add_buffer(&[0u8; 16]);
        let t_w = b.add_tensor("w", DType::F32, &[4], Some(buf));
        b.add_op(BuiltinOp::Relu, &[t_in], &[t_out], vec![]);
        b.set_io(&[t_in], &[t_out]);
        let mut blob = Vec::new();
        blob.extend_from_slice(&(t_out as u32).to_le_bytes());
        blob.extend_from_slice(&(t_w as u32).to_le_bytes());
        b.add_metadata(crate::schema::REWRITE_ALIAS_KEY, &blob);
        let m = Model::from_bytes(&b.finish()).unwrap();
        assert!(analyze_lifetimes(&m).is_err());
    }
}
