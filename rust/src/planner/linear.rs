//! Naive no-reuse planner — the Figure 4a baseline.
//!
//! Every buffer gets its own slice of the region regardless of lifetime.
//! This is what the paper's "simplistic approach" (§4.4.1) amounts to for
//! intermediates, kept as the ablation baseline for
//! `benches/bench_planner.rs`; the delta versus [`super::GreedyPlanner`]
//! is the Figure 4 memory saving.

use super::{BufferRequest, MemoryPlan, MemoryPlanner};
use crate::error::Result;

/// Allocates every buffer disjointly (no temporal reuse).
#[derive(Debug, Default, Clone, Copy)]
pub struct LinearPlanner;

impl MemoryPlanner for LinearPlanner {
    fn plan(&self, requests: &[BufferRequest], align: usize) -> Result<MemoryPlan> {
        assert!(align.is_power_of_two());
        let mut offsets = Vec::with_capacity(requests.len());
        let mut cursor = 0usize;
        for r in requests {
            offsets.push(cursor);
            cursor = (cursor + r.size + align - 1) & !(align - 1);
        }
        Ok(MemoryPlan { offsets, arena_size: cursor })
    }

    fn name(&self) -> &'static str {
        "linear"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::verify_plan;

    #[test]
    fn no_reuse_sums_sizes() {
        let reqs = vec![
            BufferRequest { size: 100, first_use: 0, last_use: 1 },
            BufferRequest { size: 100, first_use: 5, last_use: 6 }, // could share, doesn't
        ];
        let plan = LinearPlanner.plan(&reqs, 16).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.offsets, vec![0, 112]);
        assert_eq!(plan.arena_size, 224);
    }

    #[test]
    fn always_valid_by_construction() {
        let reqs: Vec<BufferRequest> = (0..20)
            .map(|i| BufferRequest { size: 10 * i + 1, first_use: 0, last_use: 100 })
            .collect();
        let plan = LinearPlanner.plan(&reqs, 4).unwrap();
        verify_plan(&reqs, &plan).unwrap();
    }
}
