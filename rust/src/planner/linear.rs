//! Naive no-reuse planner — the Figure 4a baseline.
//!
//! Every buffer gets its own slice of the region regardless of lifetime.
//! This is what the paper's "simplistic approach" (§4.4.1) amounts to for
//! intermediates, kept as the ablation baseline for
//! `benches/bench_planner.rs`; the delta versus [`super::GreedyPlanner`]
//! is the Figure 4 memory saving.

use super::{resolve_aliases, BufferRequest, MemoryPlan, MemoryPlanner};
use crate::error::Result;

/// Allocates every buffer disjointly (no temporal reuse).
#[derive(Debug, Default, Clone, Copy)]
pub struct LinearPlanner;

impl MemoryPlanner for LinearPlanner {
    fn plan(&self, requests: &[BufferRequest], align: usize) -> Result<MemoryPlan> {
        assert!(align.is_power_of_two());
        // Even the no-reuse baseline must honor alias edges: an alias is
        // a *view* of its root (same bytes by definition), not a reuse
        // optimization, so it gets the root's offset rather than its own
        // slice.
        let res = resolve_aliases(requests)?;
        let mut offsets = vec![0usize; requests.len()];
        let mut cursor = 0usize;
        for (i, r) in requests.iter().enumerate() {
            if res.root_of[i] != i {
                continue;
            }
            offsets[i] = cursor;
            cursor = (cursor + r.size + align - 1) & !(align - 1);
        }
        for i in 0..requests.len() {
            let root = res.root_of[i];
            if root != i {
                offsets[i] = offsets[root];
            }
        }
        Ok(MemoryPlan { offsets, arena_size: cursor })
    }

    fn name(&self) -> &'static str {
        "linear"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::verify_plan;

    #[test]
    fn no_reuse_sums_sizes() {
        let reqs = vec![
            BufferRequest::new(100, 0, 1),
            BufferRequest::new(100, 5, 6), // could share, doesn't
        ];
        let plan = LinearPlanner.plan(&reqs, 16).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.offsets, vec![0, 112]);
        assert_eq!(plan.arena_size, 224);
    }

    #[test]
    fn always_valid_by_construction() {
        let reqs: Vec<BufferRequest> =
            (0..20).map(|i| BufferRequest::new(10 * i + 1, 0, 100)).collect();
        let plan = LinearPlanner.plan(&reqs, 4).unwrap();
        verify_plan(&reqs, &plan).unwrap();
    }

    #[test]
    fn aliases_share_even_without_reuse() {
        // The alias gets no slice of its own — it is the root's bytes.
        let reqs = vec![
            BufferRequest::new(100, 0, 1),
            BufferRequest::new(100, 1, 2).with_alias(0),
            BufferRequest::new(50, 2, 3),
        ];
        let plan = LinearPlanner.plan(&reqs, 4).unwrap();
        verify_plan(&reqs, &plan).unwrap();
        assert_eq!(plan.offsets[1], plan.offsets[0]);
        assert_eq!(plan.offsets[2], 100);
        assert_eq!(plan.arena_size, 152);
    }
}
