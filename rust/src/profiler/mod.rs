//! Profiling hooks and the interpreter-overhead measurement (§5.4).
//!
//! `MicroProfiler` implements [`InvokeObserver`] and records one timed
//! event per op, mirroring TF Micro's `MicroProfiler` (developers
//! "instrument specific code sections ... and examine a model's
//! performance-critical paths").
//!
//! [`measure_overhead`] reproduces the paper's headline methodology
//! (Figure 6): *total* time is a plain unobserved `invoke`; *calculation*
//! time is the sum of per-kernel times; the difference, as a fraction, is
//! the interpreter overhead. Both are medians over many runs on the same
//! machine, so the ratio is robust to host noise.

use crate::error::Result;
use crate::interpreter::{InvokeObserver, MicroInterpreter};
use std::time::{Duration, Instant};

/// One timed op execution.
#[derive(Debug, Clone)]
pub struct OpEvent {
    /// Index in execution order.
    pub op_index: usize,
    /// Operator key (builtin or custom name).
    pub key: String,
    /// Wall time of the kernel's invoke.
    pub duration: Duration,
}

/// Per-op profiler; attach with [`MicroInterpreter::invoke_observed`].
#[derive(Debug, Default)]
pub struct MicroProfiler {
    events: Vec<OpEvent>,
    started: Option<(usize, Instant)>,
}

impl MicroProfiler {
    /// Fresh profiler.
    pub fn new() -> Self {
        Self::default()
    }

    /// Recorded events (all invocations, in order).
    pub fn events(&self) -> &[OpEvent] {
        &self.events
    }

    /// Total kernel ("calculation") time across recorded events.
    pub fn calculation_time(&self) -> Duration {
        self.events.iter().map(|e| e.duration).sum()
    }

    /// Aggregate time per op key, descending — the §5.4 bottleneck view.
    pub fn by_key(&self) -> Vec<(String, Duration, usize)> {
        let mut agg: Vec<(String, Duration, usize)> = Vec::new();
        for e in &self.events {
            match agg.iter_mut().find(|(k, _, _)| *k == e.key) {
                Some((_, d, n)) => {
                    *d += e.duration;
                    *n += 1;
                }
                None => agg.push((e.key.clone(), e.duration, 1)),
            }
        }
        agg.sort_by(|a, b| b.1.cmp(&a.1));
        agg
    }

    /// Drop recorded events.
    pub fn clear(&mut self) {
        self.events.clear();
    }

    /// Render a per-op table (used by `tfmicro run --profile`).
    pub fn report(&self) -> String {
        let mut s = String::from("op                        calls      total        mean\n");
        for (key, total, calls) in self.by_key() {
            s.push_str(&format!(
                "{key:<24} {calls:>6} {total:>10.3?} {:>11.3?}\n",
                total / calls as u32
            ));
        }
        s
    }
}

impl InvokeObserver for MicroProfiler {
    fn begin_op(&mut self, op_index: usize, key: &str) {
        self.events.push(OpEvent {
            op_index,
            key: key.to_string(),
            duration: Duration::ZERO,
        });
        self.started = Some((op_index, Instant::now()));
    }

    fn end_op(&mut self, op_index: usize) {
        if let Some((started_idx, t0)) = self.started.take() {
            debug_assert_eq!(started_idx, op_index);
            if let Some(e) = self.events.last_mut() {
                e.duration = t0.elapsed();
            }
        }
    }
}

/// Result of the Figure 6 methodology on the host.
#[derive(Debug, Clone, Copy)]
pub struct OverheadReport {
    /// Median wall time of one unobserved `invoke`.
    pub total: Duration,
    /// Median summed kernel time of one observed `invoke`.
    pub calculation: Duration,
    /// `max(total - calculation, 0)`.
    pub overhead: Duration,
    /// Overhead as a percentage of total.
    pub overhead_pct: f64,
}

/// Measure interpreter overhead on the host: median total invoke time vs
/// median calculation (summed kernel) time over `iters` runs each.
pub fn measure_overhead(
    interp: &mut MicroInterpreter,
    iters: usize,
) -> Result<OverheadReport> {
    assert!(iters >= 3);
    // Warmup.
    for _ in 0..3.min(iters) {
        interp.invoke()?;
    }
    // Total: unobserved invokes.
    let mut totals = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        interp.invoke()?;
        totals.push(t0.elapsed());
    }
    totals.sort();
    let total = totals[totals.len() / 2];

    // Calculation: per-op sums under the profiler.
    let mut calcs = Vec::with_capacity(iters);
    let mut prof = MicroProfiler::new();
    for _ in 0..iters {
        prof.clear();
        interp.invoke_observed(&mut prof)?;
        calcs.push(prof.calculation_time());
    }
    calcs.sort();
    let calculation = calcs[calcs.len() / 2];

    let overhead = total.saturating_sub(calculation);
    let overhead_pct = if total.is_zero() {
        0.0
    } else {
        overhead.as_secs_f64() / total.as_secs_f64() * 100.0
    };
    Ok(OverheadReport { total, calculation, overhead, overhead_pct })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiler_aggregates_by_key() {
        let mut p = MicroProfiler::new();
        p.begin_op(0, "CONV_2D");
        std::thread::sleep(Duration::from_micros(200));
        p.end_op(0);
        p.begin_op(1, "SOFTMAX");
        p.end_op(1);
        p.begin_op(2, "CONV_2D");
        p.end_op(2);
        assert_eq!(p.events().len(), 3);
        let agg = p.by_key();
        assert_eq!(agg[0].0, "CONV_2D");
        assert_eq!(agg[0].2, 2);
        assert!(p.calculation_time() >= Duration::from_micros(200));
        assert!(p.report().contains("CONV_2D"));
    }

    #[test]
    fn clear_resets() {
        let mut p = MicroProfiler::new();
        p.begin_op(0, "RELU");
        p.end_op(0);
        p.clear();
        assert!(p.events().is_empty());
        assert_eq!(p.calculation_time(), Duration::ZERO);
    }
}
